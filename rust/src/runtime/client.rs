//! PJRT client wrapper: compile HLO-text artifacts once, execute many
//! times.
//!
//! Mirrors /opt/xla-example/load_hlo: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `PjRtClient::compile`. Executables are
//! cached by artifact name, so the per-call cost on the request path is
//! literal construction + execute + copy-out.

use super::artifacts::{ArtifactEntry, Manifest};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;

/// A PJRT CPU runtime holding compiled executables for the artifact set.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl XlaRuntime {
    /// Create a CPU PJRT client and load the manifest (lazy compilation).
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Self { client, manifest, execs: HashMap::new() })
    }

    /// Load from the default artifact directory.
    pub fn from_default_dir() -> Result<Self> {
        Self::new(Manifest::load(crate::runtime::default_artifact_dir())?)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and cache the executable for an artifact.
    fn executable(&mut self, entry: &ArtifactEntry) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.execs.contains_key(&entry.name) {
            let path = self.manifest.path_of(entry);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .map_err(|e| anyhow!("parsing HLO text {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", entry.name))?;
            self.execs.insert(entry.name.clone(), exe);
        }
        Ok(&self.execs[&entry.name])
    }

    /// Execute an artifact on f32 row-major inputs with the given shapes;
    /// returns the flattened f32 output of the tuple's single element.
    pub fn run_f32(
        &mut self,
        entry: &ArtifactEntry,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<f32>> {
        // Build literals first (needs &self), then fetch/compile the
        // executable (needs &mut self).
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, dims) in inputs {
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(buf.as_ptr() as *const u8, buf.len() * 4)
            };
            let lit = xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                dims,
                bytes,
            )
            .map_err(|e| anyhow!("creating literal {dims:?}: {e:?}"))?;
            literals.push(lit);
        }
        let name = entry.name.clone();
        let exe = self.executable(entry)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = literal
            .to_tuple1()
            .map_err(|e| anyhow!("untupling result of {name}: {e:?}"))?;
        out.to_vec::<f32>()
            .map_err(|e| anyhow!("converting result of {name}: {e:?}"))
    }

    /// Number of compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.execs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::ArtifactKind;

    /// Full AOT round-trip: python-lowered HLO → PJRT compile → execute →
    /// numbers match the native implementation. Skipped when artifacts
    /// have not been built.
    #[test]
    fn cost_artifact_roundtrip_matches_native() {
        let dir = crate::runtime::default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let man = Manifest::load(&dir).unwrap();
        let entry = man
            .entries
            .iter()
            .find(|e| e.kind == ArtifactKind::Cost && e.m == 64)
            .expect("64-bucket present")
            .clone();
        let mut rt = XlaRuntime::new(man).unwrap();
        let (m, k, d) = (entry.m, entry.k, entry.d);
        let mut rng = crate::rng::Pcg32::new(99);
        let x: Vec<f32> = (0..m * d).map(|_| rng.f32()).collect();
        let c: Vec<f32> = (0..k * d).map(|_| rng.f32()).collect();
        let got = rt
            .run_f32(&entry, &[(&x, &[m, d]), (&c, &[k, d])])
            .unwrap();
        assert_eq!(got.len(), m * k);
        // Native reference.
        let mut want = vec![0f32; m * k];
        crate::runtime::backend::cost_matrix_native(&x, m, d, &c, k, &mut want);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        // Executable is cached: second call must not recompile.
        assert_eq!(rt.compiled_count(), 1);
        let _ = rt
            .run_f32(&entry, &[(&x, &[m, d]), (&c, &[k, d])])
            .unwrap();
        assert_eq!(rt.compiled_count(), 1);
    }
}
