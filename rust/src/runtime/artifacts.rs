//! Artifact manifest: the shape-bucket registry emitted by
//! `python/compile/aot.py` alongside the HLO text files.

use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Kind of compiled computation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// `batch_costs(x[M,D], c[K,D]) -> [M,K]`.
    Cost,
    /// `centroid_distances(x[N,D], mu[1,D]) -> [N]`.
    Dist,
    /// `chunk_centroid(x[N,D]) -> [1,D]` (column sums).
    Csum,
}

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: ArtifactKind,
    pub file: String,
    /// Cost: rows (objects per batch). Dist/Csum: chunk length.
    pub m: usize,
    /// Cost: columns (centroids). 1 otherwise.
    pub k: usize,
    /// Feature dimension.
    pub d: usize,
}

/// Parsed manifest plus its directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let doc = json::parse(&text).context("parsing manifest.json")?;
        let format = doc.get("format").and_then(Json::as_usize).unwrap_or(0);
        if format != 1 {
            bail!("unsupported manifest format {format}");
        }
        let mut entries = Vec::new();
        for e in doc
            .get("entries")
            .and_then(Json::as_arr)
            .context("manifest missing entries")?
        {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .context("entry missing name")?
                .to_string();
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .context("entry missing file")?
                .to_string();
            let kind = match e.get("kind").and_then(Json::as_str) {
                Some("cost") => ArtifactKind::Cost,
                Some("dist") => ArtifactKind::Dist,
                Some("csum") => ArtifactKind::Csum,
                other => bail!("entry {name}: unknown kind {other:?}"),
            };
            let get = |key: &str| e.get(key).and_then(Json::as_usize);
            let (m, k, d) = match kind {
                ArtifactKind::Cost => (
                    get("m").context("cost entry missing m")?,
                    get("k").context("cost entry missing k")?,
                    get("d").context("cost entry missing d")?,
                ),
                ArtifactKind::Dist | ArtifactKind::Csum => (
                    get("n").context("entry missing n")?,
                    1,
                    get("d").context("entry missing d")?,
                ),
            };
            if !dir.join(&file).exists() {
                bail!("artifact file missing: {file} (run `make artifacts`)");
            }
            entries.push(ArtifactEntry { name, kind, file, m, k, d });
        }
        if entries.is_empty() {
            bail!("manifest has no entries");
        }
        Ok(Self { dir, entries })
    }

    /// Smallest cost bucket that fits an `(m, k, d)` request, by padded
    /// element count. `None` means fall back to the native backend.
    pub fn pick_cost_bucket(&self, m: usize, k: usize, d: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.kind == ArtifactKind::Cost && e.m >= m && e.k >= k && e.d >= d)
            .min_by_key(|e| e.m * e.k * e.d)
    }

    /// Smallest dist bucket with chunk length >= requested and matching d.
    pub fn pick_dist_bucket(&self, d: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.kind == ArtifactKind::Dist && e.d >= d)
            .min_by_key(|e| e.d)
    }

    /// Path of an entry's HLO text file.
    pub fn path_of(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;

    fn write_fake_manifest(dir: &Path) {
        fs::create_dir_all(dir).unwrap();
        for f in ["a.hlo.txt", "b.hlo.txt", "d.hlo.txt"] {
            fs::write(dir.join(f), "HloModule fake").unwrap();
        }
        fs::write(
            dir.join("manifest.json"),
            r#"{"format":1,"entries":[
                {"name":"cost_small","kind":"cost","m":64,"k":64,"d":16,"file":"a.hlo.txt"},
                {"name":"cost_big","kind":"cost","m":256,"k":256,"d":128,"file":"b.hlo.txt"},
                {"name":"dist1","kind":"dist","n":1024,"d":32,"file":"d.hlo.txt"}
            ]}"#,
        )
        .unwrap();
    }

    #[test]
    fn loads_and_picks_buckets() {
        let dir = std::env::temp_dir().join("aba_manifest_test");
        write_fake_manifest(&dir);
        let man = Manifest::load(&dir).unwrap();
        assert_eq!(man.entries.len(), 3);
        // Fits in the small bucket.
        let b = man.pick_cost_bucket(50, 64, 10).unwrap();
        assert_eq!(b.name, "cost_small");
        // Needs the big bucket.
        let b = man.pick_cost_bucket(65, 65, 16).unwrap();
        assert_eq!(b.name, "cost_big");
        // Too big for any bucket.
        assert!(man.pick_cost_bucket(300, 300, 16).is_none());
        assert!(man.pick_cost_bucket(10, 10, 4096).is_none());
        // Dist bucket by dimension.
        assert_eq!(man.pick_dist_bucket(20).unwrap().name, "dist1");
        assert!(man.pick_dist_bucket(64).is_none());
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = Manifest::load("/nonexistent/aba").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn real_manifest_parses_if_built() {
        let dir = crate::runtime::default_artifact_dir();
        if dir.join("manifest.json").exists() {
            let man = Manifest::load(&dir).unwrap();
            assert!(man.pick_cost_bucket(64, 64, 16).is_some());
            assert!(man.entries.iter().any(|e| e.kind == ArtifactKind::Csum));
        }
    }
}
