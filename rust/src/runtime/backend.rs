//! The cost-computation backend abstraction.
//!
//! ABA's numeric hot spots — the per-batch object↔centroid cost matrix and
//! the global-centroid distance vector — go through [`CostBackend`]:
//!
//! * [`NativeBackend`] — tight Rust loops (default; fastest on this CPU).
//! * [`XlaBackend`] — the AOT Pallas/JAX artifacts through PJRT: requests
//!   are zero-padded up to the nearest shape bucket and the result is
//!   cropped. Zero-padding the feature dimension on *both* operands
//!   leaves true squared distances unchanged; padded rows/columns are
//!   cropped before the assignment solve. Oversized requests fall back to
//!   native (and are counted, so benches can report coverage).
//!
//! Both backends accept a session worker pool via
//! [`CostBackend::set_pool`]: large batch-cost requests are then split
//! into row chunks and computed concurrently (see [`super::pool`]),
//! bit-identically to the serial path.
//!
//! The actual arithmetic lives in [`super::simd`]: backends hold a
//! [`Kernels`] dispatch table (installed via [`CostBackend::set_kernels`]
//! from the session's `.kernels(..)` knob, defaulting to the
//! process-wide [`Kernels::get`]) and call its `row_norms` /
//! `cost_panel` entries — pool row-chunking composes with the kernel's
//! own L2 centroid-panel tiling.

#[cfg(feature = "xla")]
use super::artifacts::Manifest;
#[cfg(feature = "xla")]
use super::client::XlaRuntime;
use super::pool::WorkerPool;
use super::simd::{self, Kernels};
use crate::error::AbaError;
#[cfg(feature = "xla")]
use anyhow::Result;
use std::sync::Arc;

/// Which backend to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Native,
    Xla,
}

impl BackendKind {
    /// Every backend, in display order — the single source of the
    /// accepted CLI values.
    pub const ALL: [BackendKind; 2] = [BackendKind::Native, BackendKind::Xla];

    /// The canonical (CLI) spelling.
    pub const fn as_str(self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Xla => "xla",
        }
    }

    /// Accepted spellings joined with `|`, for help and error messages.
    pub fn accepted() -> String {
        Self::ALL
            .iter()
            .map(|b| b.as_str())
            .collect::<Vec<_>>()
            .join("|")
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for BackendKind {
    type Err = AbaError;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Self::ALL
            .iter()
            .copied()
            .find(|v| v.as_str() == s)
            .ok_or_else(|| {
                AbaError::InvalidInput(format!(
                    "unknown backend '{s}' (accepted: {})",
                    BackendKind::accepted()
                ))
            })
    }
}

/// Computes the ABA cost matrices. `&mut self` lets implementations keep
/// scratch buffers and compiled-executable caches.
pub trait CostBackend {
    /// Write the `m x k` squared-distance matrix between `x` (`m x d`,
    /// row-major) and centroids `c` (`k x d`) into `out` (resized).
    fn batch_costs(
        &mut self,
        x: &[f32],
        m: usize,
        d: usize,
        c: &[f32],
        k: usize,
        out: &mut Vec<f32>,
    );

    /// Squared distances from each row of `x` to a single centroid `mu`.
    fn centroid_distances(&mut self, x: &[f32], n: usize, d: usize, mu: &[f32], out: &mut Vec<f64>);

    /// Install (or clear, with `None`) the worker pool used to
    /// chunk-parallelize cost computation. The assignment loop calls
    /// this once per run from the session's [`Parallelism`] setting;
    /// backends without a parallel path may ignore it.
    ///
    /// [`Parallelism`]: super::Parallelism
    fn set_pool(&mut self, _pool: Option<Arc<WorkerPool>>) {}

    /// Install the distance-kernel dispatch table (see
    /// [`super::simd::Kernels`]). Called once per session build from the
    /// `.kernels(..)` knob; backends that do their own arithmetic (XLA)
    /// forward it to their native fallback.
    fn set_kernels(&mut self, _kernels: Kernels) {}

    /// The distance-kernel table this backend computes with — the
    /// session reads it back to install the same table on auxiliary
    /// structures (the sparse candidate index, the online handle's
    /// farthest index). Backends that ignore `set_kernels` report the
    /// process default.
    fn kernels(&self) -> Kernels {
        Kernels::get()
    }

    /// Descriptive name for logs/benches.
    fn name(&self) -> &'static str;
}

// ---------------------------------------------------------------------------
// Native backend
// ---------------------------------------------------------------------------

/// Pure-Rust backend; the perf-tuned reference implementation. With a
/// pool installed (see [`CostBackend::set_pool`]) large cost matrices
/// are chunk-parallelized over batch rows. In the deterministic kernel
/// tiers this is bit-identical to the serial path, since every entry
/// goes through the same per-entry dot ([`Kernels::cost_panel`]); the
/// fast-math tier's row-quad micro-kernel makes chunk boundaries
/// observable at the ULP level, which its relaxed contract permits.
#[derive(Default)]
pub struct NativeBackend {
    /// Scratch: per-centroid squared norms.
    c_norms: Vec<f32>,
    /// Scratch: per-batch-row squared norms.
    x_norms: Vec<f32>,
    /// Worker pool for the chunk-parallel path, shared with the owning
    /// session.
    pool: Option<Arc<WorkerPool>>,
    /// Distance-kernel dispatch table; `Default` resolves to the
    /// process-wide selection ([`Kernels::get`]), sessions may override
    /// via [`CostBackend::set_kernels`].
    kernels: Kernels,
}

/// Minimum `m * k * d` before the pooled path engages; below it, the
/// ~10us pool dispatch costs more than the loop (one 64x64x32 matrix
/// sits right at the threshold).
const PAR_COST_MIN_WORK: usize = 1 << 17;

/// Tight-loop cost matrix: `out[i*k + j] = ||x_i - c_j||^2`. One-shot
/// serial entry point over the process-default [`Kernels`] table;
/// [`NativeBackend`] adds norm scratch reuse, a per-session kernel
/// override, and optional chunk-parallelism on top.
pub fn cost_matrix_native(x: &[f32], m: usize, d: usize, c: &[f32], k: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), m * d);
    debug_assert_eq!(c.len(), k * d);
    debug_assert_eq!(out.len(), m * k);
    let kern = Kernels::get();
    let mut cn = Vec::new();
    kern.row_norms(c, k, d, &mut cn);
    let mut xn = Vec::new();
    kern.row_norms(x, m, d, &mut xn);
    kern.cost_panel(x, &xn, 0, m, d, c, &cn, k, out);
}

/// Chunk-parallel cost matrix: contiguous row chunks of `out`, one pool
/// task per chunk through [`WorkerPool::run_mut`], each chunk computed
/// by the same L2-panel-blocked [`Kernels::cost_panel`] as the serial
/// path — pool chunking composes with panel tiling, and in the
/// deterministic tiers the result is bit-identical to serial for any
/// thread count.
#[allow(clippy::too_many_arguments)]
fn cost_matrix_pooled(
    pool: &WorkerPool,
    kern: Kernels,
    x: &[f32],
    xn: &[f32],
    m: usize,
    d: usize,
    c: &[f32],
    cn: &[f32],
    k: usize,
    out: &mut [f32],
) {
    // ~4 chunks per thread for load balance without dispatch overhead.
    let chunk_rows = m.div_ceil(pool.threads() * 4).max(8);
    let mut chunks: Vec<(usize, &mut [f32])> = out
        .chunks_mut(chunk_rows * k)
        .enumerate()
        .map(|(ci, chunk)| (ci * chunk_rows, chunk))
        .collect();
    pool.run_mut(&mut chunks, &|_ti, (r0, chunk)| {
        let rows = chunk.len() / k;
        kern.cost_panel(x, xn, *r0, *r0 + rows, d, c, cn, k, chunk);
    });
}

impl CostBackend for NativeBackend {
    fn batch_costs(
        &mut self,
        x: &[f32],
        m: usize,
        d: usize,
        c: &[f32],
        k: usize,
        out: &mut Vec<f32>,
    ) {
        out.resize(m * k, 0.0);
        let kern = self.kernels;
        kern.row_norms(c, k, d, &mut self.c_norms);
        kern.row_norms(x, m, d, &mut self.x_norms);
        let (cn, xn) = (&self.c_norms[..], &self.x_norms[..]);
        match self.pool.as_deref() {
            Some(pool) if m >= 2 && m * k * d >= PAR_COST_MIN_WORK => {
                cost_matrix_pooled(pool, kern, x, xn, m, d, c, cn, k, out);
            }
            _ => kern.cost_panel(x, xn, 0, m, d, c, cn, k, out),
        }
    }

    fn centroid_distances(
        &mut self,
        x: &[f32],
        n: usize,
        d: usize,
        mu: &[f32],
        out: &mut Vec<f64>,
    ) {
        debug_assert_eq!(x.len(), n * d);
        debug_assert_eq!(mu.len(), d);
        out.clear();
        out.reserve(n);
        // Objective tier: f64 accumulation in index order, scalar in
        // every kernel mode by policy (see `runtime::simd`).
        out.extend(x.chunks_exact(d).map(|xi| simd::sq_dist(xi, mu)));
    }

    fn set_pool(&mut self, pool: Option<Arc<WorkerPool>>) {
        self.pool = pool;
    }

    fn set_kernels(&mut self, kernels: Kernels) {
        self.kernels = kernels;
    }

    fn kernels(&self) -> Kernels {
        self.kernels
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

// ---------------------------------------------------------------------------
// XLA backend (requires the `xla` feature and built artifacts)
// ---------------------------------------------------------------------------

/// PJRT-backed backend executing the AOT artifacts, with pad/crop bucket
/// dispatch and native fallback for oversized shapes.
#[cfg(feature = "xla")]
pub struct XlaBackend {
    rt: XlaRuntime,
    native: NativeBackend,
    /// Scratch buffers for padded operands.
    pad_x: Vec<f32>,
    pad_c: Vec<f32>,
    /// Telemetry: how many calls ran through XLA vs fell back.
    pub xla_calls: usize,
    pub native_fallbacks: usize,
}

#[cfg(feature = "xla")]
impl XlaBackend {
    pub fn new(manifest: Manifest) -> Result<Self> {
        Ok(Self {
            rt: XlaRuntime::new(manifest)?,
            native: NativeBackend::default(),
            pad_x: Vec::new(),
            pad_c: Vec::new(),
            xla_calls: 0,
            native_fallbacks: 0,
        })
    }

    pub fn from_default_dir() -> Result<Self> {
        Ok(Self {
            rt: XlaRuntime::from_default_dir()?,
            native: NativeBackend::default(),
            pad_x: Vec::new(),
            pad_c: Vec::new(),
            xla_calls: 0,
            native_fallbacks: 0,
        })
    }

    /// Zero-pad `src` (`rows x d`) into `dst` (`prows x pd`).
    fn pad_into(src: &[f32], rows: usize, d: usize, prows: usize, pd: usize, dst: &mut Vec<f32>) {
        dst.clear();
        dst.resize(prows * pd, 0.0);
        for i in 0..rows {
            dst[i * pd..i * pd + d].copy_from_slice(&src[i * d..(i + 1) * d]);
        }
    }
}

#[cfg(feature = "xla")]
impl CostBackend for XlaBackend {
    fn batch_costs(
        &mut self,
        x: &[f32],
        m: usize,
        d: usize,
        c: &[f32],
        k: usize,
        out: &mut Vec<f32>,
    ) {
        let Some(entry) = self.rt.manifest().pick_cost_bucket(m, k, d).cloned() else {
            self.native_fallbacks += 1;
            self.native.batch_costs(x, m, d, c, k, out);
            return;
        };
        let (bm, bk, bd) = (entry.m, entry.k, entry.d);
        Self::pad_into(x, m, d, bm, bd, &mut self.pad_x);
        Self::pad_into(c, k, d, bk, bd, &mut self.pad_c);
        let res = self
            .rt
            .run_f32(&entry, &[(&self.pad_x, &[bm, bd]), (&self.pad_c, &[bk, bd])]);
        match res {
            Ok(full) => {
                self.xla_calls += 1;
                out.clear();
                out.reserve(m * k);
                for i in 0..m {
                    out.extend_from_slice(&full[i * bk..i * bk + k]);
                }
            }
            Err(e) => {
                // An execution failure is survivable: log and fall back so
                // the pipeline keeps serving (failure-injection tested).
                log::warn!("xla batch_costs failed ({e:#}); falling back to native");
                self.native_fallbacks += 1;
                self.native.batch_costs(x, m, d, c, k, out);
            }
        }
    }

    fn centroid_distances(
        &mut self,
        x: &[f32],
        n: usize,
        d: usize,
        mu: &[f32],
        out: &mut Vec<f64>,
    ) {
        let Some(entry) = self.rt.manifest().pick_dist_bucket(d).cloned() else {
            self.native_fallbacks += 1;
            self.native.centroid_distances(x, n, d, mu, out);
            return;
        };
        let (chunk, bd) = (entry.m, entry.d);
        out.clear();
        out.reserve(n);
        // Pad mu once.
        let mut mu_pad = vec![0f32; bd];
        mu_pad[..d].copy_from_slice(mu);
        let mut start = 0usize;
        while start < n {
            let rows = (n - start).min(chunk);
            Self::pad_into(&x[start * d..(start + rows) * d], rows, d, chunk, bd, &mut self.pad_x);
            match self
                .rt
                .run_f32(&entry, &[(&self.pad_x, &[chunk, bd]), (&mu_pad, &[1, bd])])
            {
                Ok(full) => {
                    self.xla_calls += 1;
                    out.extend(full[..rows].iter().map(|&v| v as f64));
                }
                Err(e) => {
                    log::warn!("xla centroid_distances failed ({e:#}); native fallback");
                    self.native_fallbacks += 1;
                    let mut part = Vec::new();
                    self.native.centroid_distances(
                        &x[start * d..(start + rows) * d],
                        rows,
                        d,
                        mu,
                        &mut part,
                    );
                    out.extend(part);
                }
            }
            start += rows;
        }
    }

    fn set_pool(&mut self, pool: Option<Arc<WorkerPool>>) {
        // PJRT executions stay single-client; the pool accelerates the
        // native fallback path (oversized shapes, execution failures).
        self.native.set_pool(pool);
    }

    fn set_kernels(&mut self, kernels: Kernels) {
        // PJRT does its own arithmetic; the table covers the fallback.
        self.native.set_kernels(kernels);
    }

    fn kernels(&self) -> Kernels {
        self.native.kernels()
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Construct a backend by kind. XLA requires the `xla` feature and built
/// artifacts; failures surface as [`AbaError::BackendUnavailable`].
pub fn make_backend(kind: BackendKind) -> Result<Box<dyn CostBackend>, AbaError> {
    match kind {
        BackendKind::Native => Ok(Box::new(NativeBackend::default())),
        #[cfg(feature = "xla")]
        BackendKind::Xla => match XlaBackend::from_default_dir() {
            Ok(b) => Ok(Box::new(b)),
            Err(e) => Err(AbaError::BackendUnavailable(format!("{e:#}"))),
        },
        #[cfg(not(feature = "xla"))]
        BackendKind::Xla => Err(AbaError::BackendUnavailable(
            "this build has no XLA support (rebuild with `--features xla`)".into(),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn rand_mat(rng: &mut Pcg32, rows: usize, d: usize) -> Vec<f32> {
        (0..rows * d).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn native_cost_matrix_matches_direct_computation() {
        let mut rng = Pcg32::new(61);
        let (m, k, d) = (13, 7, 5);
        let x = rand_mat(&mut rng, m, d);
        let c = rand_mat(&mut rng, k, d);
        let mut out = Vec::new();
        NativeBackend::default().batch_costs(&x, m, d, &c, k, &mut out);
        for i in 0..m {
            for j in 0..k {
                let want = simd::sq_dist(&x[i * d..(i + 1) * d], &c[j * d..(j + 1) * d]);
                let got = out[i * k + j] as f64;
                assert!((got - want).abs() < 1e-3, "({i},{j}): {got} vs {want}");
            }
        }
    }

    #[test]
    fn native_centroid_distances() {
        let mut rng = Pcg32::new(62);
        let (n, d) = (20, 4);
        let x = rand_mat(&mut rng, n, d);
        let mu = rand_mat(&mut rng, 1, d);
        let mut out = Vec::new();
        NativeBackend::default().centroid_distances(&x, n, d, &mu, &mut out);
        assert_eq!(out.len(), n);
        for i in 0..n {
            let want = simd::sq_dist(&x[i * d..(i + 1) * d], &mu);
            assert!((out[i] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn pooled_cost_matrix_is_bit_identical_to_serial() {
        let mut rng = Pcg32::new(77);
        // m * k * d = 96 * 64 * 32 = 196608 >= PAR_COST_MIN_WORK, so the
        // pooled branch actually engages; +1 shapes exercise the ragged
        // last chunk and partial tiles.
        for &(m, k, d) in &[(96usize, 64usize, 32usize), (97, 65, 33)] {
            let x = rand_mat(&mut rng, m, d);
            let c = rand_mat(&mut rng, k, d);
            let mut serial = NativeBackend::default();
            let mut pooled = NativeBackend::default();
            pooled.set_pool(Some(Arc::new(WorkerPool::new(3))));
            let (mut a, mut b) = (Vec::new(), Vec::new());
            serial.batch_costs(&x, m, d, &c, k, &mut a);
            pooled.batch_costs(&x, m, d, &c, k, &mut b);
            // Exact f32 equality, not tolerance: the parallel split must
            // not change a single bit.
            assert_eq!(a, b, "m={m} k={k} d={d}");
        }
    }

    #[test]
    fn scalar_kernels_bit_identical_to_default_selection() {
        // The auto-selected vector table must not change a single bit
        // relative to the forced scalar reference (on hosts without a
        // vector ISA both tables are scalar and this holds trivially).
        let mut rng = Pcg32::new(79);
        for &(m, k, d) in &[(13usize, 7usize, 5usize), (33, 70, 16), (96, 64, 32)] {
            let x = rand_mat(&mut rng, m, d);
            let c = rand_mat(&mut rng, k, d);
            let mut auto = NativeBackend::default();
            let mut scalar = NativeBackend::default();
            scalar.set_kernels(Kernels::select(crate::runtime::KernelMode::Scalar));
            let (mut a, mut b) = (Vec::new(), Vec::new());
            auto.batch_costs(&x, m, d, &c, k, &mut a);
            scalar.batch_costs(&x, m, d, &c, k, &mut b);
            assert_eq!(a, b, "m={m} k={k} d={d}");
        }
    }

    #[test]
    fn one_shot_cost_matrix_matches_backend() {
        let mut rng = Pcg32::new(78);
        let (m, k, d) = (17, 9, 6);
        let x = rand_mat(&mut rng, m, d);
        let c = rand_mat(&mut rng, k, d);
        let mut via_backend = Vec::new();
        NativeBackend::default().batch_costs(&x, m, d, &c, k, &mut via_backend);
        let mut one_shot = vec![0f32; m * k];
        cost_matrix_native(&x, m, d, &c, k, &mut one_shot);
        assert_eq!(via_backend, one_shot);
    }

    #[test]
    fn backend_kind_display_round_trips() {
        for b in BackendKind::ALL {
            assert_eq!(b.to_string().parse::<BackendKind>().unwrap(), b);
        }
        assert_eq!(BackendKind::accepted(), "native|xla");
        let err = "gpu".parse::<BackendKind>().unwrap_err();
        assert!(err.to_string().contains("native|xla"), "{err}");
    }

    #[cfg(feature = "xla")]
    #[test]
    fn xla_backend_matches_native_with_padding() {
        let dir = crate::runtime::default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let man = Manifest::load(dir).unwrap();
        let mut xla = XlaBackend::new(man).unwrap();
        let mut native = NativeBackend::default();
        let mut rng = Pcg32::new(63);
        // Odd shapes force padding inside the 64/128 buckets.
        for &(m, k, d) in &[(10usize, 10usize, 5usize), (50, 33, 16), (100, 100, 20)] {
            let x = rand_mat(&mut rng, m, d);
            let c = rand_mat(&mut rng, k, d);
            let (mut a, mut b) = (Vec::new(), Vec::new());
            xla.batch_costs(&x, m, d, &c, k, &mut a);
            native.batch_costs(&x, m, d, &c, k, &mut b);
            assert_eq!(a.len(), b.len());
            for (u, v) in a.iter().zip(&b) {
                assert!((u - v).abs() < 1e-3, "{u} vs {v}");
            }
        }
        assert!(xla.xla_calls >= 3, "xla_calls={}", xla.xla_calls);

        // Oversized request falls back to native silently.
        let (m, k, d) = (300, 300, 12);
        let x = rand_mat(&mut rng, m, d);
        let c = rand_mat(&mut rng, k, d);
        let mut a = Vec::new();
        xla.batch_costs(&x, m, d, &c, k, &mut a);
        assert_eq!(a.len(), m * k);
        assert!(xla.native_fallbacks >= 1);

        // Distances path with chunking (n > bucket) and padding d.
        let (n, d) = (2500usize, 20usize);
        let x = rand_mat(&mut rng, n, d);
        let mu = rand_mat(&mut rng, 1, d);
        let (mut da, mut db) = (Vec::new(), Vec::new());
        xla.centroid_distances(&x, n, d, &mu, &mut da);
        native.centroid_distances(&x, n, d, &mu, &mut db);
        assert_eq!(da.len(), n);
        for (u, v) in da.iter().zip(&db) {
            assert!((u - v).abs() < 1e-2, "{u} vs {v}");
        }
    }
}
