//! CSV load/save for datasets and label vectors — lets the examples
//! exchange data with external tools and persists experiment inputs.
//!
//! Errors are typed ([`AbaError::Io`] / [`AbaError::ParseError`]); the
//! CLI boundary converts them into `anyhow` like every other library
//! error.

use super::dataset::Dataset;
use crate::error::{AbaError, AbaResult};
use std::fs;
use std::io::Write;
use std::path::Path;

fn io_err(action: &str, path: &Path, e: std::io::Error) -> AbaError {
    AbaError::Io(format!("{action} {path:?}: {e}"))
}

/// Save a dataset as headered CSV: columns `f0..f{d-1}` plus optional
/// trailing `category` column.
pub fn save(ds: &Dataset, path: impl AsRef<Path>) -> AbaResult<()> {
    let path = path.as_ref();
    let f = fs::File::create(path).map_err(|e| io_err("create", path, e))?;
    let mut w = std::io::BufWriter::new(f);
    let mut header: Vec<String> = (0..ds.d).map(|j| format!("f{j}")).collect();
    if ds.categories.is_some() {
        header.push("category".into());
    }
    writeln!(w, "{}", header.join(",")).map_err(|e| io_err("write", path, e))?;
    for i in 0..ds.n {
        let mut cells: Vec<String> =
            ds.row(i).iter().map(|v| format!("{v}")).collect();
        if let Some(c) = &ds.categories {
            cells.push(format!("{}", c[i]));
        }
        writeln!(w, "{}", cells.join(",")).map_err(|e| io_err("write", path, e))?;
    }
    Ok(())
}

/// Load a dataset from headered CSV. A trailing column literally named
/// `category` becomes the categorical feature.
pub fn load(path: impl AsRef<Path>, name: &str) -> AbaResult<Dataset> {
    let path = path.as_ref();
    let text = fs::read_to_string(path).map_err(|e| io_err("read", path, e))?;
    parse_str(&text, name)
}

/// Parse headered CSV text (the in-memory core of [`load`] — the serve
/// layer feeds request bodies through here without touching disk).
pub fn parse_str(text: &str, name: &str) -> AbaResult<Dataset> {
    let mut lines = text.lines();
    let header = lines.next().ok_or(AbaError::ParseError {
        line: 1,
        msg: "empty csv (no header)".into(),
    })?;
    let cols: Vec<&str> = header.split(',').collect();
    let has_cat = *cols.last().unwrap() == "category";
    let d = cols.len() - usize::from(has_cat);
    if d == 0 {
        return Err(AbaError::ParseError { line: 1, msg: "no feature columns".into() });
    }
    let mut x = Vec::new();
    let mut cats = Vec::new();
    for (lineno, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let cells: Vec<&str> = line.split(',').collect();
        if cells.len() != cols.len() {
            return Err(AbaError::ParseError {
                line: lineno + 2,
                msg: format!("{} cells, expected {}", cells.len(), cols.len()),
            });
        }
        for c in &cells[..d] {
            x.push(c.trim().parse::<f32>().map_err(|_| AbaError::ParseError {
                line: lineno + 2,
                msg: format!("bad float '{c}'"),
            })?);
        }
        if has_cat {
            cats.push(cells[d].trim().parse::<u32>().map_err(|_| AbaError::ParseError {
                line: lineno + 2,
                msg: format!("bad category '{}'", cells[d]),
            })?);
        }
    }
    let n = x.len() / d;
    let ds = Dataset::from_flat(name, n, d, x)?;
    if has_cat {
        ds.with_categories(cats)
    } else {
        Ok(ds)
    }
}

/// Save a label vector (one integer per line with an `label` header).
pub fn save_labels(labels: &[u32], path: impl AsRef<Path>) -> AbaResult<()> {
    let path = path.as_ref();
    let mut out = String::from("label\n");
    for l in labels {
        out.push_str(&format!("{l}\n"));
    }
    fs::write(path, out).map_err(|e| io_err("write", path, e))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthKind};

    #[test]
    fn roundtrip_without_categories() {
        let ds = generate(SynthKind::Uniform, 50, 3, 1, "rt");
        let path = std::env::temp_dir().join("aba_csv_rt.csv");
        save(&ds, &path).unwrap();
        let back = load(&path, "rt").unwrap();
        assert_eq!(back.n, ds.n);
        assert_eq!(back.d, ds.d);
        for (a, b) in ds.x.iter().zip(&back.x) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn roundtrip_with_categories() {
        let ds = generate(SynthKind::Uniform, 20, 2, 2, "rtc")
            .with_categories((0..20).map(|i| (i % 3) as u32).collect())
            .unwrap();
        let path = std::env::temp_dir().join("aba_csv_rtc.csv");
        save(&ds, &path).unwrap();
        let back = load(&path, "rtc").unwrap();
        assert_eq!(back.categories, ds.categories);
        assert_eq!(back.n_categories(), 3);
    }

    #[test]
    fn rejects_ragged_and_bad_floats_with_typed_errors() {
        let dir = std::env::temp_dir();
        let p1 = dir.join("aba_csv_bad1.csv");
        fs::write(&p1, "f0,f1\n1.0\n").unwrap();
        assert!(matches!(
            load(&p1, "x"),
            Err(AbaError::ParseError { line: 2, .. })
        ));
        let p2 = dir.join("aba_csv_bad2.csv");
        fs::write(&p2, "f0\nnotafloat\n").unwrap();
        assert!(matches!(load(&p2, "x"), Err(AbaError::ParseError { .. })));
        assert!(matches!(
            load(dir.join("aba_csv_nonexistent.csv"), "x"),
            Err(AbaError::Io(_))
        ));
    }
}
