//! The core `Dataset` type: a dense row-major `f32` feature matrix with an
//! optional categorical label per object (for the §4.3 variant).

use anyhow::{bail, Result};

/// A dataset of `n` objects with `d` features, stored row-major.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Human-readable name (catalog key or file stem).
    pub name: String,
    /// Number of objects.
    pub n: usize,
    /// Number of features.
    pub d: usize,
    /// Row-major feature matrix, length `n * d`.
    pub x: Vec<f32>,
    /// Optional per-object category in `0..n_categories` (§4.3 variant).
    pub categories: Option<Vec<u32>>,
}

impl Dataset {
    /// Build from a flat row-major buffer.
    pub fn from_flat(name: impl Into<String>, n: usize, d: usize, x: Vec<f32>) -> Result<Self> {
        if x.len() != n * d {
            bail!("buffer length {} != n*d = {}", x.len(), n * d);
        }
        if n == 0 || d == 0 {
            bail!("empty dataset (n={n}, d={d})");
        }
        Ok(Self { name: name.into(), n, d, x, categories: None })
    }

    /// Build from rows (each of length `d`).
    pub fn from_rows(name: impl Into<String>, rows: &[Vec<f32>]) -> Result<Self> {
        if rows.is_empty() {
            bail!("no rows");
        }
        let d = rows[0].len();
        let mut x = Vec::with_capacity(rows.len() * d);
        for (i, r) in rows.iter().enumerate() {
            if r.len() != d {
                bail!("row {i} has {} features, expected {d}", r.len());
            }
            x.extend_from_slice(r);
        }
        Self::from_flat(name, rows.len(), d, x)
    }

    /// The `i`-th object as a feature slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    /// Attach a categorical feature; values must be dense `0..g`.
    pub fn with_categories(mut self, cats: Vec<u32>) -> Result<Self> {
        if cats.len() != self.n {
            bail!("categories length {} != n {}", cats.len(), self.n);
        }
        self.categories = Some(cats);
        Ok(self)
    }

    /// Number of distinct categories (0 if none attached).
    pub fn n_categories(&self) -> usize {
        self.categories
            .as_ref()
            .map(|c| c.iter().copied().max().map_or(0, |m| m as usize + 1))
            .unwrap_or(0)
    }

    /// Gather a subset of objects (by index) into a new dataset; categories
    /// are carried along. Used by the hierarchical decomposition.
    pub fn subset(&self, indices: &[usize], name: impl Into<String>) -> Dataset {
        let mut x = Vec::with_capacity(indices.len() * self.d);
        for &i in indices {
            x.extend_from_slice(self.row(i));
        }
        let categories = self
            .categories
            .as_ref()
            .map(|c| indices.iter().map(|&i| c[i]).collect());
        Dataset {
            name: name.into(),
            n: indices.len(),
            d: self.d,
            x,
            categories,
        }
    }

    /// Global centroid (mean of all rows), accumulated in f64.
    pub fn global_centroid(&self) -> Vec<f32> {
        let mut acc = vec![0f64; self.d];
        for i in 0..self.n {
            let r = self.row(i);
            for (a, &v) in acc.iter_mut().zip(r) {
                *a += v as f64;
            }
        }
        acc.iter().map(|&a| (a / self.n as f64) as f32).collect()
    }

    /// Squared Euclidean distance between rows `i` and `j`.
    pub fn dist2(&self, i: usize, j: usize) -> f64 {
        sq_dist(self.row(i), self.row(j))
    }
}

/// Squared Euclidean distance between two feature slices (f64 accumulate).
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0f64;
    for (&x, &y) in a.iter().zip(b) {
        let diff = (x - y) as f64;
        s += diff * diff;
    }
    s
}

/// Squared distance from a slice to an f64 centroid.
#[inline]
pub fn sq_dist_to_f64(a: &[f32], mu: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), mu.len());
    let mut s = 0f64;
    for (&x, &m) in a.iter().zip(mu) {
        let diff = x as f64 - m;
        s += diff * diff;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::from_rows(
            "tiny",
            &[
                vec![0.0, 0.0],
                vec![1.0, 0.0],
                vec![0.0, 2.0],
                vec![3.0, 4.0],
            ],
        )
        .unwrap()
    }

    #[test]
    fn from_flat_validates() {
        assert!(Dataset::from_flat("x", 2, 3, vec![0.0; 5]).is_err());
        assert!(Dataset::from_flat("x", 0, 3, vec![]).is_err());
        assert!(Dataset::from_flat("x", 2, 3, vec![0.0; 6]).is_ok());
    }

    #[test]
    fn from_rows_checks_ragged() {
        assert!(Dataset::from_rows("x", &[vec![1.0], vec![1.0, 2.0]]).is_err());
    }

    #[test]
    fn row_access_and_dist() {
        let ds = tiny();
        assert_eq!(ds.row(1), &[1.0, 0.0]);
        assert_eq!(ds.dist2(0, 1), 1.0);
        assert_eq!(ds.dist2(0, 3), 25.0);
        assert_eq!(ds.dist2(2, 2), 0.0);
    }

    #[test]
    fn centroid_is_mean() {
        let ds = tiny();
        let mu = ds.global_centroid();
        assert!((mu[0] - 1.0).abs() < 1e-6);
        assert!((mu[1] - 1.5).abs() < 1e-6);
    }

    #[test]
    fn subset_carries_categories() {
        let ds = tiny().with_categories(vec![0, 1, 0, 1]).unwrap();
        let sub = ds.subset(&[3, 0], "sub");
        assert_eq!(sub.n, 2);
        assert_eq!(sub.row(0), &[3.0, 4.0]);
        assert_eq!(sub.categories.as_deref(), Some(&[1u32, 0][..]));
    }

    #[test]
    fn n_categories_counts_dense_labels() {
        let ds = tiny().with_categories(vec![0, 2, 1, 2]).unwrap();
        assert_eq!(ds.n_categories(), 3);
        assert_eq!(tiny().n_categories(), 0);
    }

    #[test]
    fn categories_length_checked() {
        assert!(tiny().with_categories(vec![0, 1]).is_err());
    }
}
