//! The core `Dataset` type: a dense row-major `f32` feature matrix with an
//! optional categorical label per object (for the §4.3 variant).
//!
//! `Dataset` is the *owning* type; everything downstream of the data
//! layer consumes it through the borrowed [`super::view::DataView`]
//! (`ds.view()`), which subsets by index indirection instead of copying
//! feature rows.

use super::view::DataView;
use crate::error::{AbaError, AbaResult};

/// Shared emptiness check — the single source of the `EmptyDataset`
/// rejection, used both at construction ([`Dataset::from_flat`]) and at
/// solve time ([`crate::algo::validate`]).
pub fn ensure_nonempty(n: usize) -> AbaResult<()> {
    if n == 0 {
        return Err(AbaError::EmptyDataset);
    }
    Ok(())
}

/// A dataset of `n` objects with `d` features, stored row-major.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Human-readable name (catalog key or file stem).
    pub name: String,
    /// Number of objects.
    pub n: usize,
    /// Number of features.
    pub d: usize,
    /// Row-major feature matrix, length `n * d`.
    pub x: Vec<f32>,
    /// Optional per-object category in `0..n_categories` (§4.3 variant).
    pub categories: Option<Vec<u32>>,
    /// Cached distinct-category count (`max + 1`; 0 when none). Attach
    /// categories through [`Dataset::with_categories`] — which maintains
    /// this — rather than by writing the fields directly.
    pub n_cats: usize,
}

impl Dataset {
    /// Build from a flat row-major buffer.
    pub fn from_flat(name: impl Into<String>, n: usize, d: usize, x: Vec<f32>) -> AbaResult<Self> {
        if x.len() != n * d {
            return Err(AbaError::BadShape(format!(
                "buffer length {} != n*d = {}",
                x.len(),
                n * d
            )));
        }
        ensure_nonempty(n)?;
        if d == 0 {
            return Err(AbaError::BadShape(format!("dataset has no features (n={n}, d=0)")));
        }
        Ok(Self { name: name.into(), n, d, x, categories: None, n_cats: 0 })
    }

    /// Build from rows (each of length `d`).
    pub fn from_rows(name: impl Into<String>, rows: &[Vec<f32>]) -> AbaResult<Self> {
        ensure_nonempty(rows.len())?;
        let d = rows[0].len();
        let mut x = Vec::with_capacity(rows.len() * d);
        for (i, r) in rows.iter().enumerate() {
            if r.len() != d {
                return Err(AbaError::BadShape(format!(
                    "row {i} has {} features, expected {d}",
                    r.len()
                )));
            }
            x.extend_from_slice(r);
        }
        Self::from_flat(name, rows.len(), d, x)
    }

    /// A zero-copy [`DataView`] over all rows — the entry point to every
    /// consumer layer (`partition_view`, hierarchical decomposition,
    /// kNN, k-means, ...).
    pub fn view(&self) -> DataView<'_> {
        DataView::from(self)
    }

    /// The `i`-th object as a feature slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    /// Attach a categorical feature; values must be dense `0..g`. Caches
    /// the category count so [`Dataset::n_categories`] (and views) never
    /// rescan.
    pub fn with_categories(mut self, cats: Vec<u32>) -> AbaResult<Self> {
        if cats.len() != self.n {
            return Err(AbaError::BadShape(format!(
                "categories length {} != n {}",
                cats.len(),
                self.n
            )));
        }
        self.n_cats = cats.iter().copied().max().map_or(0, |m| m as usize + 1);
        self.categories = Some(cats);
        Ok(self)
    }

    /// Number of distinct categories (0 if none attached). O(1) off the
    /// cache when categories were attached via
    /// [`Dataset::with_categories`]; falls back to a rescan when a
    /// caller wrote the pub `categories` field directly and left the
    /// cache stale (`n_cats == 0` while categories are present) — so
    /// direct writes stay correct, they just forfeit the caching.
    pub fn n_categories(&self) -> usize {
        if self.n_cats == 0 {
            if let Some(c) = &self.categories {
                return c.iter().copied().max().map_or(0, |m| m as usize + 1);
            }
        }
        self.n_cats
    }

    /// Gather a subset of objects (by index) into a new owned dataset;
    /// categories are carried along. A thin wrapper over
    /// `view().select(..).materialize(..)` for tests and experiments
    /// that genuinely need an owned copy — the hot paths (hierarchical
    /// decomposition, pool fan-out) pass index views instead and never
    /// materialize.
    pub fn subset(&self, indices: &[usize], name: impl Into<String>) -> Dataset {
        self.view().select(indices).materialize(name)
    }

    /// Global centroid (mean of all rows), accumulated in f64.
    pub fn global_centroid(&self) -> Vec<f32> {
        self.view().global_centroid()
    }

    /// Squared Euclidean distance between rows `i` and `j`.
    pub fn dist2(&self, i: usize, j: usize) -> f64 {
        sq_dist(self.row(i), self.row(j))
    }
}

/// Squared Euclidean distance between two feature slices — the
/// objective-tier (f64-accumulating) `dist2`, re-exported from the one
/// definition in [`crate::runtime::simd`] so `Dataset`, `DataView`, the
/// kNN modules, and the backend verification paths all share it. See
/// that module for the accumulation-precision policy.
pub use crate::runtime::simd::sq_dist;

/// Squared distance from a slice to an f64 centroid (same policy; see
/// [`crate::runtime::simd`]).
pub use crate::runtime::simd::sq_dist_to_f64;

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::from_rows(
            "tiny",
            &[
                vec![0.0, 0.0],
                vec![1.0, 0.0],
                vec![0.0, 2.0],
                vec![3.0, 4.0],
            ],
        )
        .unwrap()
    }

    #[test]
    fn from_flat_validates_with_typed_errors() {
        assert!(matches!(
            Dataset::from_flat("x", 2, 3, vec![0.0; 5]),
            Err(AbaError::BadShape(_))
        ));
        assert_eq!(
            Dataset::from_flat("x", 0, 3, vec![]).unwrap_err(),
            AbaError::EmptyDataset
        );
        assert!(matches!(
            Dataset::from_flat("x", 2, 0, vec![]),
            Err(AbaError::BadShape(_))
        ));
        assert!(Dataset::from_flat("x", 2, 3, vec![0.0; 6]).is_ok());
    }

    #[test]
    fn from_rows_checks_ragged() {
        assert!(matches!(
            Dataset::from_rows("x", &[vec![1.0], vec![1.0, 2.0]]),
            Err(AbaError::BadShape(_))
        ));
        assert_eq!(Dataset::from_rows("x", &[]).unwrap_err(), AbaError::EmptyDataset);
    }

    #[test]
    fn row_access_and_dist() {
        let ds = tiny();
        assert_eq!(ds.row(1), &[1.0, 0.0]);
        assert_eq!(ds.dist2(0, 1), 1.0);
        assert_eq!(ds.dist2(0, 3), 25.0);
        assert_eq!(ds.dist2(2, 2), 0.0);
    }

    #[test]
    fn centroid_is_mean() {
        let ds = tiny();
        let mu = ds.global_centroid();
        assert!((mu[0] - 1.0).abs() < 1e-6);
        assert!((mu[1] - 1.5).abs() < 1e-6);
    }

    #[test]
    fn subset_carries_categories() {
        let ds = tiny().with_categories(vec![0, 1, 0, 1]).unwrap();
        let sub = ds.subset(&[3, 0], "sub");
        assert_eq!(sub.n, 2);
        assert_eq!(sub.row(0), &[3.0, 4.0]);
        assert_eq!(sub.categories.as_deref(), Some(&[1u32, 0][..]));
    }

    #[test]
    fn n_categories_cached_at_attach_time() {
        let ds = tiny().with_categories(vec![0, 2, 1, 2]).unwrap();
        assert_eq!(ds.n_categories(), 3);
        assert_eq!(ds.n_cats, 3);
        assert_eq!(tiny().n_categories(), 0);
        // Subsets carry the cached count instead of rescanning.
        assert_eq!(ds.subset(&[0, 2], "sub").n_categories(), 3);
    }

    #[test]
    fn n_categories_survives_direct_field_writes() {
        // The struct's fields are pub; a direct write leaves the cache
        // stale and must fall back to a rescan (both on the dataset and
        // through views built from it).
        let mut ds = tiny();
        ds.categories = Some(vec![0, 1, 4, 1]);
        assert_eq!(ds.n_cats, 0);
        assert_eq!(ds.n_categories(), 5);
        assert_eq!(ds.view().n_categories(), 5);
    }

    #[test]
    fn categories_length_checked() {
        assert!(matches!(
            tiny().with_categories(vec![0, 1]),
            Err(AbaError::BadShape(_))
        ));
    }
}
