//! Zero-copy views over feature matrices — the currency of every
//! consumer layer.
//!
//! A [`DataView`] is a borrowed (matrix, optional index indirection,
//! optional categories) triple. Constructing one from a [`Dataset`] is
//! free, and selecting any index subset of an existing view
//! ([`DataView::select`]) borrows the index slice instead of gathering
//! feature rows — which is what lets the hierarchical driver descend
//! through arbitrarily deep decompositions without copying the `n x d`
//! matrix once per level. The only feature-row copies left on the hot
//! path are the bounded per-batch stagings ([`DataView::gather_rows`] /
//! [`DataView::gather_range`]), and those are metered: see
//! [`gathered_bytes`].

use super::dataset::Dataset;
use std::borrow::Cow;
use std::sync::atomic::{AtomicU64, Ordering};

/// Bytes of feature data gathered (copied) through view helpers and
/// [`Dataset::subset`] since the last [`reset_gathered_bytes`]. Process
/// wide; used by the benches to make the zero-copy win machine-readable.
static GATHERED_BYTES: AtomicU64 = AtomicU64::new(0);

/// Total feature bytes gathered process-wide since the last reset.
pub fn gathered_bytes() -> u64 {
    GATHERED_BYTES.load(Ordering::Relaxed)
}

/// Reset the gather meter (benches call this before a measured run).
pub fn reset_gathered_bytes() {
    GATHERED_BYTES.store(0, Ordering::Relaxed);
}

fn count_gathered(rows: usize, d: usize) {
    GATHERED_BYTES.fetch_add((rows * d * std::mem::size_of::<f32>()) as u64, Ordering::Relaxed);
}

/// A borrowed, possibly index-indirected window onto a feature matrix.
///
/// Row `i` of the view is row `idx[i]` of the underlying matrix (or row
/// `i` itself for an identity view). Categories, when present, are
/// indirected the same way, and the distinct-category count is carried
/// through subsetting instead of being rescanned.
#[derive(Clone, Debug)]
pub struct DataView<'a> {
    /// Human-readable name inherited from the backing dataset.
    name: &'a str,
    /// The underlying row-major matrix (the *parent* rows, not the
    /// view's).
    x: &'a [f32],
    /// Features per row.
    d: usize,
    /// Rows visible through the view.
    n: usize,
    /// Optional indirection: view row `i` -> parent row `idx[i]`.
    /// `Borrowed` when selecting out of an identity view (the common
    /// hierarchical case — zero allocation), `Owned` only when composing
    /// a selection on top of an already-selected view.
    idx: Option<Cow<'a, [usize]>>,
    /// Parent-indexed categories.
    categories: Option<&'a [u32]>,
    /// Cached distinct-category count (0 when none attached).
    n_cats: usize,
}

impl<'a> DataView<'a> {
    /// Identity view over a raw row-major matrix (no dataset needed —
    /// e.g. the constrained loop's super-object matrix).
    pub fn over(name: &'a str, x: &'a [f32], n: usize, d: usize) -> Self {
        assert_eq!(x.len(), n * d, "matrix length {} != n*d = {}", x.len(), n * d);
        Self { name, x, d, n, idx: None, categories: None, n_cats: 0 }
    }

    /// Rows visible through the view.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Features per row.
    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    /// Name inherited from the backing dataset.
    pub fn name(&self) -> &'a str {
        self.name
    }

    /// Map a view row to its parent row.
    #[inline]
    fn parent_row(&self, i: usize) -> usize {
        match &self.idx {
            Some(idx) => idx[i],
            None => i,
        }
    }

    /// The `i`-th view row as a feature slice.
    #[inline]
    pub fn row(&self, i: usize) -> &'a [f32] {
        let p = self.parent_row(i) * self.d;
        &self.x[p..p + self.d]
    }

    /// Whether a categorical feature is attached.
    pub fn has_categories(&self) -> bool {
        self.categories.is_some()
    }

    /// Category of view row `i`. Panics when no categories are attached
    /// (callers gate on [`Self::has_categories`] / `n_categories() > 0`).
    #[inline]
    pub fn category(&self, i: usize) -> u32 {
        self.categories.expect("view has no categories")[self.parent_row(i)]
    }

    /// Cached distinct-category count (0 when none attached). Carried
    /// through [`Self::select`] — never rescans the labels.
    pub fn n_categories(&self) -> usize {
        self.n_cats
    }

    /// The view's categories in view-row order: borrowed (zero-copy) for
    /// identity views, gathered for indirected ones (`n` u32s, never
    /// feature rows).
    pub fn categories(&self) -> Option<Cow<'a, [u32]>> {
        let cats = self.categories?;
        Some(match &self.idx {
            None => Cow::Borrowed(cats),
            Some(idx) => Cow::Owned(idx.iter().map(|&p| cats[p]).collect()),
        })
    }

    /// The contiguous backing matrix, if the view is an identity view
    /// (fast path for backends that consume whole matrices).
    pub fn contiguous(&self) -> Option<&'a [f32]> {
        match self.idx {
            None => Some(self.x),
            Some(_) => None,
        }
    }

    /// Select a subset of view rows, for free: no feature row is copied.
    /// `indices` hold *view-local* row ids; selecting out of an identity
    /// view borrows them directly, selecting out of an already-selected
    /// view composes the indirection (one `Vec<usize>`, never `n x d`
    /// floats).
    pub fn select<'b>(&self, indices: &'b [usize]) -> DataView<'b>
    where
        'a: 'b,
    {
        debug_assert!(indices.iter().all(|&i| i < self.n), "selection out of range");
        let idx: Cow<'b, [usize]> = match &self.idx {
            None => Cow::Borrowed(indices),
            Some(parent) => Cow::Owned(indices.iter().map(|&i| parent[i]).collect()),
        };
        DataView {
            name: self.name,
            x: self.x,
            d: self.d,
            n: indices.len(),
            idx: Some(idx),
            categories: self.categories,
            n_cats: self.n_cats,
        }
    }

    /// Gather the given view rows contiguously into `dst` (resized).
    /// This is the *bounded* staging copy of the assignment loop (one
    /// batch at a time) — metered by [`gathered_bytes`].
    pub fn gather_rows(&self, rows: &[usize], dst: &mut Vec<f32>) {
        let d = self.d;
        dst.resize(rows.len() * d, 0.0);
        for (j, &i) in rows.iter().enumerate() {
            dst[j * d..(j + 1) * d].copy_from_slice(self.row(i));
        }
        count_gathered(rows.len(), d);
    }

    /// Gather the contiguous view-row range `lo..hi` into `dst`
    /// (resized) — for callers that need to tile an index view through
    /// an API that consumes whole contiguous matrices.
    pub fn gather_range(&self, lo: usize, hi: usize, dst: &mut Vec<f32>) {
        let d = self.d;
        dst.resize((hi - lo) * d, 0.0);
        for (j, i) in (lo..hi).enumerate() {
            dst[j * d..(j + 1) * d].copy_from_slice(self.row(i));
        }
        count_gathered(hi - lo, d);
    }

    /// Mean of all view rows, accumulated in f64.
    pub fn global_centroid(&self) -> Vec<f32> {
        let mut acc = vec![0f64; self.d];
        for i in 0..self.n {
            crate::runtime::simd::add_assign_row(&mut acc, self.row(i));
        }
        acc.iter().map(|&a| (a / self.n as f64) as f32).collect()
    }

    /// Squared Euclidean distance between view rows `i` and `j` — the
    /// objective-tier f64-accumulating `dist2` (one definition for the
    /// whole crate; see [`crate::runtime::simd`] for the policy).
    pub fn dist2(&self, i: usize, j: usize) -> f64 {
        crate::runtime::simd::sq_dist(self.row(i), self.row(j))
    }

    /// Materialize the view into an owned [`Dataset`] (gathers every
    /// row — metered). The escape hatch for tests and experiments that
    /// genuinely need an owned copy; hot paths stay on views.
    pub fn materialize(&self, name: impl Into<String>) -> Dataset {
        let mut x = Vec::with_capacity(self.n * self.d);
        for i in 0..self.n {
            x.extend_from_slice(self.row(i));
        }
        count_gathered(self.n, self.d);
        let categories = self.categories().map(Cow::into_owned);
        Dataset {
            name: name.into(),
            n: self.n,
            d: self.d,
            x,
            categories,
            n_cats: self.n_cats,
        }
    }
}

impl<'a> From<&'a Dataset> for DataView<'a> {
    fn from(ds: &'a Dataset) -> Self {
        Self {
            name: &ds.name,
            x: &ds.x,
            d: ds.d,
            n: ds.n,
            idx: None,
            categories: ds.categories.as_deref(),
            // Through the accessor, not the field: it repairs a stale
            // cache when `categories` was written directly.
            n_cats: ds.n_categories(),
        }
    }
}

impl<'a> From<&'_ DataView<'a>> for DataView<'a> {
    fn from(view: &DataView<'a>) -> Self {
        view.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::from_rows(
            "tiny",
            &[
                vec![0.0, 0.0],
                vec![1.0, 0.0],
                vec![0.0, 2.0],
                vec![3.0, 4.0],
            ],
        )
        .unwrap()
    }

    #[test]
    fn identity_view_mirrors_dataset() {
        let ds = tiny();
        let v = ds.view();
        assert_eq!((v.n(), v.d()), (4, 2));
        assert_eq!(v.name(), "tiny");
        assert_eq!(v.row(3), ds.row(3));
        assert_eq!(v.dist2(0, 1), ds.dist2(0, 1));
        assert_eq!(v.contiguous(), Some(&ds.x[..]));
        assert_eq!(v.global_centroid(), ds.global_centroid());
    }

    #[test]
    fn select_is_zero_copy_and_composes() {
        let ds = tiny();
        let v = ds.view();
        let idx = [3usize, 0, 2];
        let sub = v.select(&idx);
        assert_eq!(sub.n(), 3);
        assert_eq!(sub.row(0), &[3.0, 4.0]);
        assert!(sub.contiguous().is_none());
        // Composed selection maps through the parent's indirection.
        let comp = [1usize, 2];
        let subsub = sub.select(&comp);
        assert_eq!(subsub.row(0), &[0.0, 0.0]);
        assert_eq!(subsub.row(1), &[0.0, 2.0]);
    }

    #[test]
    fn categories_carried_with_cached_count() {
        let ds = tiny().with_categories(vec![0, 2, 1, 2]).unwrap();
        let v = ds.view();
        assert_eq!(v.n_categories(), 3);
        assert_eq!(v.category(1), 2);
        assert_eq!(v.categories().unwrap().as_ref(), &[0, 2, 1, 2]);
        let idx = [3usize, 0];
        let sub = v.select(&idx);
        // Count carries through without a rescan (stays the parent's).
        assert_eq!(sub.n_categories(), 3);
        assert_eq!(sub.category(0), 2);
        assert_eq!(sub.categories().unwrap().as_ref(), &[2, 0]);
    }

    #[test]
    fn materialize_matches_owned_subset() {
        let ds = tiny().with_categories(vec![0, 1, 0, 1]).unwrap();
        let idx = [3usize, 0];
        let owned = ds.subset(&idx, "sub");
        let via_view = ds.view().select(&idx).materialize("sub");
        assert_eq!(owned.x, via_view.x);
        assert_eq!(owned.categories, via_view.categories);
        assert_eq!(owned.n_categories(), via_view.n_categories());
    }

    #[test]
    fn gather_helpers_stage_rows_and_meter_bytes() {
        let ds = tiny();
        let v = ds.view();
        let before = gathered_bytes();
        let mut buf = Vec::new();
        v.gather_rows(&[2, 0], &mut buf);
        assert_eq!(buf, vec![0.0, 2.0, 0.0, 0.0]);
        v.gather_range(1, 3, &mut buf);
        assert_eq!(buf, vec![1.0, 0.0, 0.0, 2.0]);
        assert_eq!(gathered_bytes() - before, (4 * 2 * 4) as u64);
    }

    #[test]
    fn raw_matrix_views() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let v = DataView::over("raw", &x, 2, 2);
        assert_eq!(v.row(1), &[3.0, 4.0]);
        assert!(!v.has_categories());
        assert_eq!(v.n_categories(), 0);
        assert!(v.categories().is_none());
    }
}
