//! Datasets: in-memory feature matrices, zero-copy [`DataView`]s over
//! them (the currency of every consumer layer), synthetic generators
//! mirroring the paper's Table 2 catalog, preprocessing, CSV I/O, and
//! k-means (used to derive the categorical feature for the Table 9/10
//! experiments, as Croella et al. 2025 do).

pub mod csv;
pub mod dataset;
pub mod kmeans;
pub mod kplus;
pub mod preprocess;
pub mod synth;
pub mod view;

pub use dataset::Dataset;
pub use view::DataView;
