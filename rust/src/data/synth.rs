//! Synthetic dataset generators and the Table-2 catalog.
//!
//! The paper evaluates on 21 UCI/Kaggle/ImageNet datasets (Table 2). This
//! environment has no network access, so each catalog entry is replaced by
//! a deterministic synthetic generator whose *geometry* matches what the
//! algorithms actually consume: a standardized tabular matrix with cluster
//! structure (Gaussian mixtures), one-hot/binary blocks, heavy-tailed
//! columns, or pixel-like bounded features. See DESIGN.md §3 for the
//! substitution rationale. Each entry carries the paper's (N, D) and a
//! scaled-down (N, D) used by default on this single-core box.

use super::dataset::Dataset;
use crate::error::{AbaError, AbaResult};
use crate::rng::Pcg32;

/// Kind of synthetic geometry to generate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SynthKind {
    /// Isotropic Gaussian mixture: standardized tabular data with latent
    /// cluster structure (the typical UCI-numeric geometry).
    GaussianMixture { components: usize, spread: f32 },
    /// Uniform in `[0, 1)^D` — structureless control.
    Uniform,
    /// Bernoulli(p) binary matrix (Plants / Npi style one-hot surveys).
    Binary { p: f32 },
    /// Student-t-ish heavy-tailed columns (finance-style outliers),
    /// generated as normal / sqrt(chi2/k) with k = 3.
    HeavyTail,
    /// Image-like: class templates + pixel noise, clipped to `[0, 1]`
    /// (Mnist / Cifar / Imagenet stand-in; features scaled by 1/255 in the
    /// paper, i.e. bounded [0,1]).
    ImageLike { classes: usize },
}

/// Generate a deterministic synthetic dataset.
pub fn generate(kind: SynthKind, n: usize, d: usize, seed: u64, name: &str) -> Dataset {
    let mut rng = Pcg32::new(seed);
    let mut x = vec![0f32; n * d];
    match kind {
        SynthKind::GaussianMixture { components, spread } => {
            let k = components.max(1);
            // Component means drawn once; covariance identity.
            let mut means = vec![0f32; k * d];
            for m in means.iter_mut() {
                *m = rng.normal_f32(0.0, spread);
            }
            for i in 0..n {
                let c = rng.gen_index(k);
                let mu = &means[c * d..(c + 1) * d];
                for j in 0..d {
                    x[i * d + j] = mu[j] + rng.normal_f32(0.0, 1.0);
                }
            }
        }
        SynthKind::Uniform => {
            for v in x.iter_mut() {
                *v = rng.f32();
            }
        }
        SynthKind::Binary { p } => {
            for v in x.iter_mut() {
                *v = if rng.bernoulli(p as f64) { 1.0 } else { 0.0 };
            }
        }
        SynthKind::HeavyTail => {
            let k = 3.0f64;
            for v in x.iter_mut() {
                let z = rng.normal();
                let chi2: f64 = (0..3).map(|_| rng.normal().powi(2)).sum();
                *v = (z / (chi2 / k).sqrt()) as f32;
            }
        }
        SynthKind::ImageLike { classes } => {
            let k = classes.max(1);
            let mut templates = vec![0f32; k * d];
            for t in templates.iter_mut() {
                *t = rng.f32();
            }
            for i in 0..n {
                let c = rng.gen_index(k);
                let t = &templates[c * d..(c + 1) * d];
                for j in 0..d {
                    let v = t[j] + rng.normal_f32(0.0, 0.15);
                    x[i * d + j] = v.clamp(0.0, 1.0);
                }
            }
        }
    }
    Dataset::from_flat(name, n, d, x).expect("generator produced valid shape")
}

/// One row of the Table-2 catalog with paper-scale and small-scale sizes.
#[derive(Clone, Debug)]
pub struct CatalogEntry {
    pub name: &'static str,
    /// Paper's (N, D).
    pub paper_n: usize,
    pub paper_d: usize,
    /// Scaled-down (N, D) used by default in this repo's experiments.
    pub small_n: usize,
    pub small_d: usize,
    pub kind: SynthKind,
    pub seed: u64,
}

/// Which scale of the catalog to instantiate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Scale {
    /// Paper-scale N and D — only practical for the smaller entries.
    Paper,
    /// Scaled-down sizes that run in seconds on one core.
    Small,
    /// Very small, for tests.
    Tiny,
}

/// The catalog mirroring Table 2 of the paper.
pub fn catalog() -> Vec<CatalogEntry> {
    use SynthKind::*;
    let gm = |c, s| GaussianMixture { components: c, spread: s };
    vec![
        CatalogEntry { name: "abalone", paper_n: 4_177, paper_d: 10, small_n: 4_177, small_d: 10, kind: gm(5, 2.0), seed: 101 },
        CatalogEntry { name: "travel", paper_n: 5_454, paper_d: 24, small_n: 5_454, small_d: 24, kind: gm(6, 2.5), seed: 102 },
        CatalogEntry { name: "facebook", paper_n: 7_050, paper_d: 13, small_n: 7_050, small_d: 13, kind: gm(4, 2.0), seed: 103 },
        CatalogEntry { name: "frogs", paper_n: 7_195, paper_d: 22, small_n: 7_195, small_d: 22, kind: gm(10, 3.0), seed: 104 },
        CatalogEntry { name: "electric", paper_n: 10_000, paper_d: 12, small_n: 10_000, small_d: 12, kind: gm(2, 1.0), seed: 105 },
        CatalogEntry { name: "npi", paper_n: 10_440, paper_d: 40, small_n: 10_440, small_d: 40, kind: Binary { p: 0.5 }, seed: 106 },
        CatalogEntry { name: "pulsar", paper_n: 17_898, paper_d: 8, small_n: 17_898, small_d: 8, kind: gm(2, 3.0), seed: 107 },
        CatalogEntry { name: "creditcard", paper_n: 30_000, paper_d: 24, small_n: 15_000, small_d: 24, kind: HeavyTail, seed: 108 },
        CatalogEntry { name: "adult", paper_n: 32_561, paper_d: 110, small_n: 16_000, small_d: 48, kind: gm(8, 1.5), seed: 109 },
        CatalogEntry { name: "plants", paper_n: 34_781, paper_d: 70, small_n: 17_000, small_d: 70, kind: Binary { p: 0.12 }, seed: 110 },
        CatalogEntry { name: "bank", paper_n: 45_211, paper_d: 53, small_n: 20_000, small_d: 53, kind: gm(6, 1.5), seed: 111 },
        CatalogEntry { name: "cifar10", paper_n: 50_000, paper_d: 3_072, small_n: 10_000, small_d: 256, kind: ImageLike { classes: 10 }, seed: 112 },
        CatalogEntry { name: "mnist", paper_n: 60_000, paper_d: 784, small_n: 12_000, small_d: 196, kind: ImageLike { classes: 10 }, seed: 113 },
        CatalogEntry { name: "survival", paper_n: 110_204, paper_d: 4, small_n: 40_000, small_d: 4, kind: gm(3, 2.0), seed: 114 },
        CatalogEntry { name: "diabetes", paper_n: 253_680, paper_d: 22, small_n: 60_000, small_d: 22, kind: gm(4, 1.0), seed: 115 },
        CatalogEntry { name: "music", paper_n: 515_345, paper_d: 91, small_n: 80_000, small_d: 64, kind: gm(12, 2.0), seed: 116 },
        CatalogEntry { name: "covtype", paper_n: 581_012, paper_d: 55, small_n: 100_000, small_d: 55, kind: gm(7, 2.5), seed: 117 },
        CatalogEntry { name: "imagenet8", paper_n: 1_281_167, paper_d: 192, small_n: 120_000, small_d: 96, kind: ImageLike { classes: 100 }, seed: 118 },
        CatalogEntry { name: "imagenet32", paper_n: 1_281_167, paper_d: 3_072, small_n: 131_072, small_d: 64, kind: ImageLike { classes: 100 }, seed: 119 },
        CatalogEntry { name: "census", paper_n: 2_458_285, paper_d: 68, small_n: 150_000, small_d: 68, kind: gm(9, 1.5), seed: 120 },
        CatalogEntry { name: "finance", paper_n: 6_362_620, paper_d: 12, small_n: 200_000, small_d: 12, kind: HeavyTail, seed: 121 },
    ]
}

/// Instantiate a catalog dataset by name at the given scale.
pub fn load(name: &str, scale: Scale) -> AbaResult<Dataset> {
    let Some(e) = catalog().into_iter().find(|e| e.name == name) else {
        return Err(AbaError::InvalidInput(format!(
            "unknown dataset '{name}'; known: {}",
            catalog().iter().map(|e| e.name).collect::<Vec<_>>().join(", ")
        )));
    };
    let (n, d) = match scale {
        Scale::Paper => (e.paper_n, e.paper_d),
        Scale::Small => (e.small_n, e.small_d),
        Scale::Tiny => ((e.small_n / 20).clamp(200, 2_000), e.small_d.min(16)),
    };
    Ok(generate(e.kind, n, d, e.seed, e.name))
}

impl std::str::FromStr for Scale {
    type Err = AbaError;
    fn from_str(s: &str) -> AbaResult<Self> {
        match s {
            "paper" => Ok(Scale::Paper),
            "small" => Ok(Scale::Small),
            "tiny" => Ok(Scale::Tiny),
            _ => Err(AbaError::InvalidInput(format!("unknown scale '{s}' (paper|small|tiny)"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = generate(SynthKind::Uniform, 100, 5, 7, "a");
        let b = generate(SynthKind::Uniform, 100, 5, 7, "b");
        assert_eq!(a.x, b.x);
        let c = generate(SynthKind::Uniform, 100, 5, 8, "c");
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn binary_is_binary() {
        let ds = generate(SynthKind::Binary { p: 0.3 }, 500, 8, 1, "b");
        assert!(ds.x.iter().all(|&v| v == 0.0 || v == 1.0));
        let ones = ds.x.iter().filter(|&&v| v == 1.0).count();
        let frac = ones as f64 / ds.x.len() as f64;
        assert!((0.25..0.35).contains(&frac), "frac={frac}");
    }

    #[test]
    fn image_like_bounded() {
        let ds = generate(SynthKind::ImageLike { classes: 3 }, 200, 16, 2, "i");
        assert!(ds.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn mixture_has_spread_structure() {
        let ds = generate(
            SynthKind::GaussianMixture { components: 2, spread: 50.0 },
            400,
            2,
            3,
            "g",
        );
        // With spread >> noise, the per-coordinate variance must far exceed 1.
        let mu = ds.global_centroid();
        let var: f64 = (0..ds.n)
            .map(|i| super::super::dataset::sq_dist(ds.row(i), &mu))
            .sum::<f64>()
            / ds.n as f64;
        assert!(var > 10.0, "var={var}");
    }

    #[test]
    fn catalog_names_unique_and_loadable_tiny() {
        let cat = catalog();
        let mut names: Vec<_> = cat.iter().map(|e| e.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), cat.len());
        let ds = load("travel", Scale::Tiny).unwrap();
        assert!(ds.n >= 200 && ds.d <= 16);
        assert!(load("nonexistent", Scale::Tiny).is_err());
    }

    #[test]
    fn catalog_matches_paper_sizes() {
        let cat = catalog();
        let im32 = cat.iter().find(|e| e.name == "imagenet32").unwrap();
        assert_eq!(im32.paper_n, 1_281_167);
        assert_eq!(im32.paper_d, 3_072);
        assert_eq!(cat.len(), 21); // Table 2 has 21 datasets
    }

    #[test]
    fn heavy_tail_has_outliers() {
        let ds = generate(SynthKind::HeavyTail, 2_000, 4, 5, "h");
        let max = ds.x.iter().fold(0f32, |m, &v| m.max(v.abs()));
        assert!(max > 5.0, "max={max}");
    }
}
