//! K-plus data augmentation (Papenberg 2024), discussed in §3.3 of the
//! paper: plain Euclidean anticlustering only aligns anticluster *means*;
//! appending, for each original feature, its powers of deviation from the
//! dataset mean makes the objective also align higher moments (variance,
//! skew, ...) across anticlusters.
//!
//! `kplus_augment(ds, m)` appends `m - 1` extra blocks of D features:
//! block `p` holds `(x_id - mean_d)^(p+1)` for p = 1..m-1, each block
//! standardized so no moment dominates. ABA then runs unchanged on the
//! augmented matrix — exactly the usage the paper describes (at the cost
//! of dimensionality, which it also notes).

use super::dataset::Dataset;
use super::preprocess::standardize;
use super::view::DataView;

/// Append deviation-moment features up to the `moments`-th moment
/// (`moments = 1` returns a plain copy; `2` adds squared deviations, ...).
/// Accepts a `&Dataset` or any zero-copy [`DataView`] subset; the output
/// is necessarily owned (it is new data).
pub fn kplus_augment<'a>(data: impl Into<DataView<'a>>, moments: usize) -> Dataset {
    assert!(moments >= 1, "moments must be >= 1");
    let ds: DataView<'a> = data.into();
    let (n, d) = (ds.n(), ds.d());
    let extra = moments - 1;
    let d2 = d * (1 + extra);
    // Column means of the original features.
    let mut means = vec![0f64; d];
    for i in 0..n {
        crate::runtime::simd::add_assign_row(&mut means, ds.row(i));
    }
    for m in means.iter_mut() {
        *m /= n as f64;
    }
    let mut x = vec![0f32; n * d2];
    for i in 0..n {
        let row = ds.row(i);
        x[i * d2..i * d2 + d].copy_from_slice(row);
        for p in 0..extra {
            for j in 0..d {
                let dev = row[j] as f64 - means[j];
                x[i * d2 + d * (p + 1) + j] = dev.powi(p as i32 + 2) as f32;
            }
        }
    }
    let mut out = Dataset::from_flat(format!("{}+kplus{moments}", ds.name()), n, d2, x)
        .expect("augmented matrix has a valid shape");
    if let Some(cats) = ds.categories() {
        out = out
            .with_categories(cats.into_owned())
            .expect("category length matches by construction");
    }
    // Standardize the whole augmented matrix so each moment block
    // contributes comparably (Papenberg 2024's recommendation).
    standardize(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthKind};
    use crate::solver::{Aba, Anticlusterer};

    #[test]
    fn moments_one_is_identity_shape() {
        let ds = generate(SynthKind::Uniform, 50, 3, 1, "k1");
        let out = kplus_augment(&ds, 1);
        assert_eq!(out.d, 3);
        assert_eq!(out.n, 50);
    }

    #[test]
    fn moments_two_doubles_dimensionality() {
        let ds = generate(SynthKind::Uniform, 50, 3, 2, "k2");
        let out = kplus_augment(&ds, 2);
        assert_eq!(out.d, 6);
        // Augmented block is the squared deviation (before
        // standardization it would be >= 0; after, just finite).
        assert!(out.x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn kplus_balances_variance_across_anticlusters() {
        // A dataset with two variance regimes: half the points tight
        // around 0, half widely spread. Plain ABA balances means;
        // k-plus(2) must additionally balance within-anticluster
        // variance of the ORIGINAL feature.
        let n = 400;
        let mut rng = crate::rng::Pcg32::new(5);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|i| {
                let sd = if i < n / 2 { 0.1 } else { 5.0 };
                vec![rng.normal_f32(0.0, sd), rng.normal_f32(0.0, sd)]
            })
            .collect();
        let ds = Dataset::from_rows("var", &rows).unwrap();
        let k = 8;

        let var_spread = |labels: &[u32]| {
            // Spread of per-anticluster variance of feature 0.
            let mut vars = Vec::new();
            for c in 0..k as u32 {
                let vals: Vec<f64> = (0..n)
                    .filter(|&i| labels[i] == c)
                    .map(|i| ds.row(i)[0] as f64)
                    .collect();
                let mean = vals.iter().sum::<f64>() / vals.len() as f64;
                let var =
                    vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / vals.len() as f64;
                vars.push(var);
            }
            let max = vars.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            let min = vars.iter().copied().fold(f64::INFINITY, f64::min);
            max - min
        };

        let mut session = Aba::new().unwrap();
        let plain = session.partition(&ds, k).unwrap().labels;
        let aug = kplus_augment(&ds, 2);
        let kplus = session.partition(&aug, k).unwrap().labels;
        // k-plus must not be (much) worse at balancing variance; on this
        // construction it is typically strictly better.
        let (ps, ks) = (var_spread(&plain), var_spread(&kplus));
        assert!(ks <= ps * 1.10, "plain spread {ps} vs kplus {ks}");
    }

    #[test]
    #[should_panic(expected = "moments")]
    fn zero_moments_rejected() {
        let ds = generate(SynthKind::Uniform, 10, 2, 3, "k0");
        kplus_augment(&ds, 0);
    }
}
