//! Preprocessing mirroring §5.1 of the paper: z-score standardization for
//! tabular data, 1/255-style min-max scaling for image data, and one-hot
//! encoding of integer categorical columns.

use super::dataset::Dataset;
use super::view::DataView;

/// Z-score standardize every column in place (columns with zero variance
/// are centered only). Returns per-column (mean, sd) for reuse.
pub fn standardize(ds: &mut Dataset) -> Vec<(f32, f32)> {
    let (n, d) = (ds.n, ds.d);
    let mut stats = Vec::with_capacity(d);
    for j in 0..d {
        let mut s = 0f64;
        let mut s2 = 0f64;
        for i in 0..n {
            let v = ds.x[i * d + j] as f64;
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = (s2 / n as f64 - mean * mean).max(0.0);
        let sd = var.sqrt();
        let denom = if sd > 1e-12 { sd } else { 1.0 };
        for i in 0..n {
            let v = &mut ds.x[i * d + j];
            *v = ((*v as f64 - mean) / denom) as f32;
        }
        stats.push((mean as f32, sd as f32));
    }
    stats
}

/// Min-max scale every column into `[0, 1]` in place (constant columns
/// become 0). The image datasets in the paper are scaled by 1/255, which
/// this generalizes.
pub fn minmax_scale(ds: &mut Dataset) {
    let (n, d) = (ds.n, ds.d);
    for j in 0..d {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for i in 0..n {
            let v = ds.x[i * d + j];
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let span = hi - lo;
        for i in 0..n {
            let v = &mut ds.x[i * d + j];
            *v = if span > 0.0 { (*v - lo) / span } else { 0.0 };
        }
    }
}

/// One-hot encode an integer label column into `k` binary features appended
/// to a copy of the data (paper §5.1: "one binary feature per category").
/// Accepts a `&Dataset` or any zero-copy [`DataView`] subset.
pub fn append_one_hot<'a>(data: impl Into<DataView<'a>>, labels: &[u32]) -> Dataset {
    let ds: DataView<'a> = data.into();
    let (n, d) = (ds.n(), ds.d());
    assert_eq!(labels.len(), n);
    let k = labels.iter().copied().max().map_or(0, |m| m as usize + 1);
    let d2 = d + k;
    let mut x = vec![0f32; n * d2];
    for i in 0..n {
        x[i * d2..i * d2 + d].copy_from_slice(ds.row(i));
        x[i * d2 + d + labels[i] as usize] = 1.0;
    }
    let out = Dataset::from_flat(format!("{}+onehot", ds.name()), n, d2, x)
        .expect("one-hot matrix has a valid shape");
    match ds.categories() {
        Some(cats) => out
            .with_categories(cats.into_owned())
            .expect("category length matches by construction"),
        None => out,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthKind};

    #[test]
    fn standardize_zero_mean_unit_sd() {
        let mut ds = generate(SynthKind::GaussianMixture { components: 3, spread: 5.0 }, 1_000, 4, 1, "g");
        standardize(&mut ds);
        for j in 0..ds.d {
            let mut s = 0f64;
            let mut s2 = 0f64;
            for i in 0..ds.n {
                let v = ds.x[i * ds.d + j] as f64;
                s += v;
                s2 += v * v;
            }
            let mean = s / ds.n as f64;
            let var = s2 / ds.n as f64 - mean * mean;
            assert!(mean.abs() < 1e-4, "col {j} mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "col {j} var {var}");
        }
    }

    #[test]
    fn standardize_constant_column_is_centered() {
        let mut ds = Dataset::from_rows("c", &[vec![3.0, 1.0], vec![3.0, 2.0]]).unwrap();
        standardize(&mut ds);
        assert_eq!(ds.x[0], 0.0);
        assert_eq!(ds.x[2], 0.0);
    }

    #[test]
    fn minmax_into_unit_interval() {
        let mut ds = Dataset::from_rows("m", &[vec![-5.0, 7.0], vec![5.0, 7.0], vec![0.0, 7.0]]).unwrap();
        minmax_scale(&mut ds);
        assert_eq!(ds.row(0), &[0.0, 0.0]);
        assert_eq!(ds.row(1), &[1.0, 0.0]);
        assert_eq!(ds.row(2), &[0.5, 0.0]);
    }

    #[test]
    fn one_hot_appends_indicator_block() {
        let ds = Dataset::from_rows("o", &[vec![1.0], vec![2.0], vec![3.0]]).unwrap();
        let out = append_one_hot(&ds, &[2, 0, 2]);
        assert_eq!(out.d, 4);
        assert_eq!(out.row(0), &[1.0, 0.0, 0.0, 1.0]);
        assert_eq!(out.row(1), &[2.0, 1.0, 0.0, 0.0]);
        assert_eq!(out.row(2), &[3.0, 0.0, 0.0, 1.0]);
    }
}
