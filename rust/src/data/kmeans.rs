//! Lloyd's k-means with k-means++ seeding.
//!
//! Used for (a) deriving the categorical feature of the Table 9/10
//! experiments exactly as Croella et al. (2025) do, and (b) as a geometry
//! probe in tests. Deterministic given the seed.

use super::view::DataView;
use crate::rng::Pcg32;
use crate::runtime::simd::{add_assign_row, sq_dist_to_f64};

/// Result of a k-means run.
#[derive(Clone, Debug)]
pub struct KMeansResult {
    /// Cluster index per object.
    pub labels: Vec<u32>,
    /// Row-major `k x d` centroids.
    pub centroids: Vec<f64>,
    /// Final within-cluster sum of squared distances.
    pub inertia: f64,
    /// Iterations executed.
    pub iterations: usize,
}

/// Run k-means. Accepts anything that views as a feature matrix — a
/// `&Dataset` or a zero-copy [`DataView`] subset (the Table 9/10
/// categorical derivation runs on views without gathering rows).
pub fn kmeans<'a>(
    data: impl Into<DataView<'a>>,
    k: usize,
    max_iter: usize,
    seed: u64,
) -> KMeansResult {
    let ds: DataView<'a> = data.into();
    let n = ds.n();
    assert!((1..=n).contains(&k), "k={k} out of range for n={n}");
    let d = ds.d();
    let mut rng = Pcg32::new(seed);
    let mut centroids = plus_plus_init(&ds, k, &mut rng);
    let mut labels = vec![0u32; n];
    let mut inertia = f64::INFINITY;
    let mut iterations = 0;

    for it in 0..max_iter {
        iterations = it + 1;
        // Assignment step.
        let mut new_inertia = 0f64;
        for i in 0..n {
            let row = ds.row(i);
            let mut best = 0usize;
            let mut best_d = f64::INFINITY;
            for c in 0..k {
                let dist = sq_dist_to_f64(row, &centroids[c * d..(c + 1) * d]);
                if dist < best_d {
                    best_d = dist;
                    best = c;
                }
            }
            labels[i] = best as u32;
            new_inertia += best_d;
        }
        // Update step.
        let mut sums = vec![0f64; k * d];
        let mut counts = vec![0usize; k];
        for i in 0..n {
            let c = labels[i] as usize;
            counts[c] += 1;
            add_assign_row(&mut sums[c * d..(c + 1) * d], ds.row(i));
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Re-seed an empty cluster at a random point.
                let p = rng.gen_index(n);
                for (dst, &v) in centroids[c * d..(c + 1) * d].iter_mut().zip(ds.row(p)) {
                    *dst = v as f64;
                }
                continue;
            }
            for j in 0..d {
                centroids[c * d + j] = sums[c * d + j] / counts[c] as f64;
            }
        }
        // Convergence: relative inertia improvement below tolerance.
        if (inertia - new_inertia).abs() <= 1e-9 * inertia.max(1.0) {
            inertia = new_inertia;
            break;
        }
        inertia = new_inertia;
    }
    KMeansResult { labels, centroids, inertia, iterations }
}

/// k-means++ seeding (D² sampling).
fn plus_plus_init(ds: &DataView<'_>, k: usize, rng: &mut Pcg32) -> Vec<f64> {
    let (n, d) = (ds.n(), ds.d());
    let mut centroids = vec![0f64; k * d];
    let first = rng.gen_index(n);
    for (dst, &v) in centroids[..d].iter_mut().zip(ds.row(first)) {
        *dst = v as f64;
    }
    let mut min_d2 = vec![f64::INFINITY; n];
    for c in 1..k {
        // Update nearest-centroid distances with the last added centroid.
        let prev = &centroids[(c - 1) * d..c * d];
        let mut total = 0f64;
        for i in 0..n {
            let dist = sq_dist_to_f64(ds.row(i), prev);
            if dist < min_d2[i] {
                min_d2[i] = dist;
            }
            total += min_d2[i];
        }
        // Sample proportional to D²; fall back to uniform if degenerate.
        let pick = if total > 0.0 {
            let mut target = rng.f64() * total;
            let mut chosen = n - 1;
            for i in 0..n {
                target -= min_d2[i];
                if target <= 0.0 {
                    chosen = i;
                    break;
                }
            }
            chosen
        } else {
            rng.gen_index(n)
        };
        for (dst, &v) in centroids[c * d..(c + 1) * d].iter_mut().zip(ds.row(pick)) {
            *dst = v as f64;
        }
    }
    centroids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthKind};

    #[test]
    fn recovers_separated_clusters() {
        // 3 well-separated blobs: k-means must reach near-zero inertia
        // relative to blob separation and produce 3 non-empty clusters.
        let ds = generate(
            SynthKind::GaussianMixture { components: 3, spread: 50.0 },
            600,
            4,
            9,
            "blobs",
        );
        let res = kmeans(&ds, 3, 100, 42);
        let mut counts = [0usize; 3];
        for &l in &res.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c > 50), "{counts:?}");
        // Per-point inertia should be near the noise floor (d * 1.0), and
        // in any case orders of magnitude below the blob separation
        // (spread^2 = 2500). A single k-means++ start occasionally lands
        // a slightly suboptimal local optimum, hence the slack.
        let per_point = res.inertia / ds.n as f64;
        assert!(per_point < 30.0, "per_point={per_point}");
    }

    #[test]
    fn k_equals_one_gives_global_centroid() {
        let ds = generate(SynthKind::Uniform, 300, 3, 4, "u");
        let res = kmeans(&ds, 1, 10, 0);
        let mu = ds.global_centroid();
        for j in 0..ds.d {
            assert!((res.centroids[j] - mu[j] as f64).abs() < 1e-3);
        }
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let ds = generate(SynthKind::Uniform, 200, 2, 5, "u");
        let a = kmeans(&ds, 4, 50, 7);
        let b = kmeans(&ds, 4, 50, 7);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn labels_dense_in_range() {
        let ds = generate(SynthKind::Uniform, 100, 2, 6, "u");
        let res = kmeans(&ds, 5, 20, 1);
        assert!(res.labels.iter().all(|&l| l < 5));
    }

    #[test]
    fn view_subset_matches_owned_subset() {
        let ds = generate(SynthKind::Uniform, 120, 3, 7, "u");
        let idx: Vec<usize> = (0..120).step_by(3).collect();
        let owned = kmeans(&ds.subset(&idx, "owned"), 4, 30, 9);
        let viewed = kmeans(&ds.view().select(&idx), 4, 30, 9);
        assert_eq!(owned.labels, viewed.labels);
        assert_eq!(owned.centroids, viewed.centroids);
    }
}
