//! In-repo SGD trainer — the pipeline's consumer.
//!
//! A logistic-regression model trained by mini-batch SGD, used by the
//! end-to-end example to quantify the paper's motivating claim: batches
//! that are representative of the whole dataset (anticlusters) give
//! lower-variance gradients than random batches, which shows up as a
//! smoother per-batch loss trajectory at equal data budget.

use crate::data::DataView;
use crate::rng::Pcg32;

/// Binary logistic regression trained with plain SGD.
#[derive(Clone, Debug)]
pub struct LogReg {
    pub w: Vec<f64>,
    pub b: f64,
    pub lr: f64,
}

impl LogReg {
    pub fn new(d: usize, lr: f64) -> Self {
        Self { w: vec![0.0; d], b: 0.0, lr }
    }

    #[inline]
    fn margin(&self, x: &[f32]) -> f64 {
        let mut z = self.b;
        for (wi, &xi) in self.w.iter().zip(x) {
            z += wi * xi as f64;
        }
        z
    }

    /// Mean log-loss of the model on the given rows. Accepts a
    /// `&Dataset` or a zero-copy [`DataView`].
    pub fn loss<'a>(&self, data: impl Into<DataView<'a>>, y: &[f32], indices: &[usize]) -> f64 {
        let ds: DataView<'a> = data.into();
        let mut total = 0f64;
        for &i in indices {
            let z = self.margin(ds.row(i));
            let p = sigmoid(z);
            let yi = y[i] as f64;
            total -= yi * (p.max(1e-12)).ln() + (1.0 - yi) * ((1.0 - p).max(1e-12)).ln();
        }
        total / indices.len().max(1) as f64
    }

    /// One SGD step on a mini-batch (mean gradient); returns the batch
    /// loss *before* the update.
    pub fn train_batch<'a>(
        &mut self,
        data: impl Into<DataView<'a>>,
        y: &[f32],
        indices: &[usize],
    ) -> f64 {
        let ds: DataView<'a> = data.into();
        let m = indices.len().max(1) as f64;
        let mut grad_w = vec![0f64; self.w.len()];
        let mut grad_b = 0f64;
        let mut loss = 0f64;
        for &i in indices {
            let x = ds.row(i);
            let p = sigmoid(self.margin(x));
            let yi = y[i] as f64;
            loss -= yi * (p.max(1e-12)).ln() + (1.0 - yi) * ((1.0 - p).max(1e-12)).ln();
            let err = p - yi;
            for (g, &xi) in grad_w.iter_mut().zip(x) {
                *g += err * xi as f64;
            }
            grad_b += err;
        }
        for (w, g) in self.w.iter_mut().zip(&grad_w) {
            *w -= self.lr * g / m;
        }
        self.b -= self.lr * grad_b / m;
        loss / m
    }

    /// Classification accuracy at threshold 0.5.
    pub fn accuracy<'a>(&self, data: impl Into<DataView<'a>>, y: &[f32]) -> f64 {
        let ds: DataView<'a> = data.into();
        let n = ds.n();
        let correct = (0..n)
            .filter(|&i| (self.margin(ds.row(i)) > 0.0) == (y[i] > 0.5))
            .count();
        correct as f64 / n as f64
    }
}

#[inline]
fn sigmoid(z: f64) -> f64 {
    1.0 / (1.0 + (-z).exp())
}

/// Synthesize binary labels from a random ground-truth hyperplane with
/// the given label-noise rate. Returns labels in {0.0, 1.0}.
pub fn synth_labels<'a>(data: impl Into<DataView<'a>>, noise: f64, seed: u64) -> Vec<f32> {
    let ds: DataView<'a> = data.into();
    let mut rng = Pcg32::new(seed);
    let w: Vec<f64> = (0..ds.d()).map(|_| rng.normal()).collect();
    (0..ds.n())
        .map(|i| {
            let z: f64 = ds
                .row(i)
                .iter()
                .zip(&w)
                .map(|(&x, &wi)| x as f64 * wi)
                .sum();
            let mut y = z > 0.0;
            if rng.bernoulli(noise) {
                y = !y;
            }
            f32::from(y)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthKind};

    #[test]
    fn learns_separable_labels() {
        let ds = generate(SynthKind::Uniform, 600, 6, 81, "s");
        let y = synth_labels(&ds, 0.0, 3);
        let mut model = LogReg::new(ds.d, 0.5);
        let all: Vec<usize> = (0..ds.n).collect();
        let initial = model.loss(&ds, &y, &all);
        for _ in 0..200 {
            model.train_batch(&ds, &y, &all);
        }
        let final_loss = model.loss(&ds, &y, &all);
        assert!(final_loss < initial * 0.5, "{initial} -> {final_loss}");
        assert!(model.accuracy(&ds, &y) > 0.9);
    }

    #[test]
    fn loss_decreases_with_minibatches() {
        let ds = generate(SynthKind::Uniform, 400, 4, 82, "s");
        let y = synth_labels(&ds, 0.05, 4);
        let mut model = LogReg::new(ds.d, 0.3);
        let all: Vec<usize> = (0..ds.n).collect();
        let initial = model.loss(&ds, &y, &all);
        for epoch in 0..20 {
            for b in 0..10 {
                let batch: Vec<usize> = (0..40).map(|i| (b * 40 + i + epoch) % 400).collect();
                model.train_batch(&ds, &y, &batch);
            }
        }
        assert!(model.loss(&ds, &y, &all) < initial);
    }

    #[test]
    fn label_noise_rate_respected() {
        let ds = generate(SynthKind::Uniform, 2_000, 3, 83, "s");
        let clean = synth_labels(&ds, 0.0, 7);
        let noisy = synth_labels(&ds, 0.2, 7);
        let flips = clean
            .iter()
            .zip(&noisy)
            .filter(|(a, b)| a != b)
            .count();
        let rate = flips as f64 / 2_000.0;
        assert!((0.15..0.25).contains(&rate), "rate={rate}");
    }
}
