//! Streaming mini-batch pipeline — the L3 coordination layer for the
//! paper's machine-learning application (§1: "creating mini-batches for
//! stochastic gradient descent in neural network training").
//!
//! A producer thread partitions the dataset into K anticlusters (each
//! anticluster = one representative mini-batch) and streams them through
//! a bounded channel to the training consumer; the bound provides
//! backpressure, so a slow consumer throttles production instead of
//! ballooning memory. The consumer in [`sgd`] is a real in-repo
//! logistic-regression trainer used by the end-to-end example to compare
//! ABA-built batches against random shuffling.

pub mod sgd;

use crate::algo::AbaConfig;
use crate::baselines::random_part;
use crate::data::DataView;
use crate::error::{AbaError, AbaResult};
use crate::online::OnlinePartition;
use crate::solver::{Aba, Anticlusterer};
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::time::Instant;

/// How each epoch's mini-batches are constructed.
#[derive(Clone, Debug)]
pub enum BatchStrategy {
    /// Anticlusters from ABA (deterministic; batch *order* reshuffled per
    /// epoch with the given seed).
    Aba { cfg: AbaConfig, shuffle_seed: u64 },
    /// Anticlusters maintained by **one** [`OnlinePartition`] across
    /// epochs: epoch 0 partitions once, every later epoch applies
    /// `churn` remove+reinsert operations (a rotating window over the
    /// dataset, modeling arriving/expiring rows) followed by a bounded
    /// `refine` — instead of re-partitioning from scratch. Batch order
    /// reshuffles per epoch like [`BatchStrategy::Aba`].
    Evolving {
        cfg: AbaConfig,
        shuffle_seed: u64,
        /// Rows removed and re-inserted per epoch (clamped to `n`).
        churn: usize,
        /// Candidate-swap budget for the per-epoch refine pass.
        refine_budget: usize,
    },
    /// Classic random shuffling into equal batches, reseeded per epoch.
    Random { seed: u64 },
}

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Number of mini-batches per epoch (= anticlusters K).
    pub k: usize,
    pub epochs: usize,
    /// Bounded-channel depth (backpressure window).
    pub queue_depth: usize,
    pub strategy: BatchStrategy,
}

/// One mini-batch flowing through the pipeline.
#[derive(Clone, Debug)]
pub struct MiniBatch {
    pub epoch: usize,
    pub index: usize,
    /// Object indices into the dataset.
    pub indices: Vec<usize>,
}

/// Aggregate pipeline statistics.
#[derive(Clone, Debug, Default)]
pub struct PipelineStats {
    pub batches_produced: usize,
    pub batches_consumed: usize,
    /// Seconds the producer spent building partitions.
    pub produce_secs: f64,
    /// Seconds the producer spent blocked on the full queue (backpressure).
    pub blocked_secs: f64,
    /// Wall-clock of the whole run.
    pub total_secs: f64,
}

/// Run the pipeline: produce mini-batches per `cfg`, invoke `consumer`
/// for each. The consumer runs on the caller's thread; production runs on
/// a worker thread with backpressure `queue_depth`. Accepts a `&Dataset`
/// or a zero-copy [`DataView`] subset — building per-epoch batches over
/// a fold or shard costs no feature-row copy.
pub fn run_pipeline<'a>(
    data: impl Into<DataView<'a>>,
    cfg: &PipelineConfig,
    mut consumer: impl FnMut(&MiniBatch),
) -> AbaResult<PipelineStats> {
    let view: DataView<'a> = data.into();
    let n = view.n();
    if cfg.k == 0 || cfg.k > n {
        return Err(AbaError::InvalidK {
            k: cfg.k,
            n,
            reason: "mini-batch count must be in 1..=n".into(),
        });
    }
    let t0 = Instant::now();
    let (tx, rx) = mpsc::sync_channel::<MiniBatch>(cfg.queue_depth.max(1));
    let mut stats = PipelineStats::default();

    let produced = std::thread::scope(|scope| -> AbaResult<(usize, f64, f64)> {
        let view = &view;
        let producer = scope.spawn(move || -> AbaResult<(usize, f64, f64)> {
            let mut produced = 0usize;
            let mut produce_secs = 0f64;
            let mut blocked_secs = 0f64;
            // ABA partitions are deterministic: compute once, reuse across
            // epochs (only the batch order changes). The evolving
            // strategy keeps ONE OnlinePartition alive instead, applying
            // per-epoch churn; random reshuffles each epoch.
            let mut aba_batches: Option<Vec<Vec<usize>>> = None;
            // Evolving state: the live handle plus the current row id of
            // every view row (ids change as rows are removed/reinserted).
            let mut evolving: Option<(OnlinePartition, Vec<u64>)> = None;
            for epoch in 0..cfg.epochs {
                let tp = Instant::now();
                let batches: Vec<Vec<usize>> = match &cfg.strategy {
                    BatchStrategy::Aba { cfg: aba_cfg, shuffle_seed } => {
                        if aba_batches.is_none() {
                            // One session per pipeline; ABA partitions are
                            // deterministic, so its Partition::groups()
                            // are computed once and reused across epochs.
                            let mut session = Aba::from_config(aba_cfg.clone())?;
                            aba_batches = Some(session.partition_view(view, cfg.k)?.groups());
                        }
                        let mut order: Vec<usize> = (0..cfg.k).collect();
                        let mut rng =
                            crate::rng::Pcg32::new(shuffle_seed.wrapping_add(epoch as u64));
                        rng.shuffle(&mut order);
                        let groups = aba_batches.as_ref().unwrap();
                        order.into_iter().map(|g| groups[g].clone()).collect()
                    }
                    BatchStrategy::Evolving {
                        cfg: aba_cfg,
                        shuffle_seed,
                        churn,
                        refine_budget,
                    } => {
                        if evolving.is_none() {
                            // Epoch 0: one full partition into a live
                            // handle; ids are 0..n in view-row order.
                            let mut session = Aba::from_config(aba_cfg.clone())?;
                            let handle = session.partition_online(view, cfg.k)?;
                            evolving = Some((handle, (0..n as u64).collect()));
                        } else if *churn > 0 {
                            // Later epochs: remove + reinsert a rotating
                            // window of rows (the dataset churn), then a
                            // bounded refine — never a full re-solve.
                            let (handle, ids) = evolving.as_mut().unwrap();
                            let c = (*churn).min(n);
                            let start = (epoch - 1) * c;
                            let rows: Vec<usize> =
                                (0..c).map(|j| (start + j) % n).collect();
                            let gone: Vec<u64> = rows.iter().map(|&r| ids[r]).collect();
                            handle.remove(&gone)?;
                            let sub = view.select(&rows);
                            let fresh = handle.insert_batch(&sub)?;
                            for (&r, id) in rows.iter().zip(fresh) {
                                ids[r] = id;
                            }
                            handle.refine(*refine_budget);
                        }
                        let (handle, ids) = evolving.as_ref().unwrap();
                        let row_of: BTreeMap<u64, usize> =
                            ids.iter().enumerate().map(|(r, &id)| (id, r)).collect();
                        let mut groups: Vec<Vec<usize>> = handle
                            .groups_ids()
                            .into_iter()
                            .map(|g| g.iter().map(|id| row_of[id]).collect())
                            .collect();
                        let mut order: Vec<usize> = (0..cfg.k).collect();
                        let mut rng =
                            crate::rng::Pcg32::new(shuffle_seed.wrapping_add(epoch as u64));
                        rng.shuffle(&mut order);
                        order
                            .into_iter()
                            .map(|g| std::mem::take(&mut groups[g]))
                            .collect()
                    }
                    BatchStrategy::Random { seed } => {
                        let labels = random_part::random_partition(
                            n,
                            cfg.k,
                            seed.wrapping_add(epoch as u64),
                        );
                        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); cfg.k];
                        for (i, &l) in labels.iter().enumerate() {
                            groups[l as usize].push(i);
                        }
                        groups
                    }
                };
                produce_secs += tp.elapsed().as_secs_f64();
                for (index, indices) in batches.into_iter().enumerate() {
                    let tb = Instant::now();
                    if tx.send(MiniBatch { epoch, index, indices }).is_err() {
                        // Consumer hung up — stop producing.
                        return Ok((produced, produce_secs, blocked_secs));
                    }
                    blocked_secs += tb.elapsed().as_secs_f64();
                    produced += 1;
                }
            }
            Ok((produced, produce_secs, blocked_secs))
        });

        for batch in rx.iter() {
            consumer(&batch);
            stats.batches_consumed += 1;
        }
        producer.join().expect("producer panicked")
    })?;

    stats.batches_produced = produced.0;
    stats.produce_secs = produced.1;
    stats.blocked_secs = produced.2;
    stats.total_secs = t0.elapsed().as_secs_f64();
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthKind};
    use crate::data::Dataset;

    fn ds() -> Dataset {
        generate(SynthKind::Uniform, 120, 4, 71, "p")
    }

    #[test]
    fn every_object_appears_once_per_epoch() {
        let ds = ds();
        let cfg = PipelineConfig {
            k: 6,
            epochs: 3,
            queue_depth: 2,
            strategy: BatchStrategy::Aba {
                cfg: AbaConfig::default(),
                shuffle_seed: 1,
            },
        };
        let mut seen: Vec<Vec<usize>> = vec![vec![0; 120]; 3];
        let stats = run_pipeline(&ds, &cfg, |b| {
            for &i in &b.indices {
                seen[b.epoch][i] += 1;
            }
        })
        .unwrap();
        assert_eq!(stats.batches_produced, 18);
        assert_eq!(stats.batches_consumed, 18);
        for epoch in &seen {
            assert!(epoch.iter().all(|&c| c == 1));
        }
    }

    #[test]
    fn batch_sizes_balanced() {
        let ds = ds();
        let cfg = PipelineConfig {
            k: 7, // 120 / 7 -> sizes 17/18
            epochs: 1,
            queue_depth: 4,
            strategy: BatchStrategy::Random { seed: 3 },
        };
        let mut sizes = Vec::new();
        run_pipeline(&ds, &cfg, |b| sizes.push(b.indices.len())).unwrap();
        let (min, max) = (
            *sizes.iter().min().unwrap(),
            *sizes.iter().max().unwrap(),
        );
        assert!(max - min <= 1, "{sizes:?}");
    }

    #[test]
    fn aba_batch_order_reshuffles_across_epochs() {
        let ds = ds();
        let cfg = PipelineConfig {
            k: 10,
            epochs: 2,
            queue_depth: 32,
            strategy: BatchStrategy::Aba {
                cfg: AbaConfig::default(),
                shuffle_seed: 9,
            },
        };
        let mut firsts: Vec<Vec<usize>> = vec![Vec::new(); 2];
        run_pipeline(&ds, &cfg, |b| firsts[b.epoch].push(b.indices[0])).unwrap();
        // Same batch *set* each epoch, different order.
        let mut a = firsts[0].clone();
        let mut b = firsts[1].clone();
        assert_ne!(firsts[0], firsts[1]);
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn invalid_k_is_a_typed_error() {
        let ds = ds();
        let cfg = PipelineConfig {
            k: 0,
            epochs: 1,
            queue_depth: 1,
            strategy: BatchStrategy::Random { seed: 1 },
        };
        assert!(matches!(
            run_pipeline(&ds, &cfg, |_| {}),
            Err(crate::error::AbaError::InvalidK { .. })
        ));
    }

    #[test]
    fn backpressure_counts_blocking() {
        let ds = ds();
        let cfg = PipelineConfig {
            k: 12,
            epochs: 2,
            queue_depth: 1,
            strategy: BatchStrategy::Random { seed: 5 },
        };
        let stats = run_pipeline(&ds, &cfg, |_| {
            std::thread::sleep(std::time::Duration::from_millis(2));
        })
        .unwrap();
        // With a slow consumer and queue depth 1, the producer must have
        // spent measurable time blocked — and the accounting must
        // balance: nothing produced is dropped on the floor.
        assert!(stats.blocked_secs > 0.0, "{stats:?}");
        assert_eq!(stats.batches_produced, stats.batches_consumed, "{stats:?}");
        assert_eq!(stats.batches_consumed, 24);
    }

    #[test]
    fn evolving_strategy_covers_every_object_each_epoch() {
        // The Evolving strategy maintains ONE OnlinePartition across
        // epochs (churn + refine instead of re-partitioning); each
        // epoch's batches must still cover the dataset exactly once,
        // balanced, with bookkeeping intact.
        let ds = ds();
        let epochs = 3;
        let cfg = PipelineConfig {
            k: 6,
            epochs,
            queue_depth: 4,
            strategy: BatchStrategy::Evolving {
                cfg: AbaConfig::default(),
                shuffle_seed: 5,
                churn: 20,
                refine_budget: 2_000,
            },
        };
        let mut seen: Vec<Vec<usize>> = vec![vec![0; 120]; epochs];
        let mut sizes: Vec<usize> = Vec::new();
        let stats = run_pipeline(&ds, &cfg, |b| {
            sizes.push(b.indices.len());
            for &i in &b.indices {
                seen[b.epoch][i] += 1;
            }
        })
        .unwrap();
        assert_eq!(stats.batches_produced, 6 * epochs);
        assert_eq!(stats.batches_produced, stats.batches_consumed);
        for epoch in &seen {
            assert!(epoch.iter().all(|&c| c == 1), "coverage broken");
        }
        let (min, max) = (*sizes.iter().min().unwrap(), *sizes.iter().max().unwrap());
        assert!(max - min <= 1, "{sizes:?}");
    }

    #[test]
    fn evolving_without_churn_matches_aba_batches() {
        // churn = 0 degenerates to the static ABA strategy: same handle
        // across epochs, nothing moves, so the batch *sets* coincide
        // with the one-shot partition's groups.
        let ds = ds();
        let k = 5;
        let run = |strategy: BatchStrategy| {
            let cfg = PipelineConfig { k, epochs: 2, queue_depth: 8, strategy };
            let mut got: Vec<Vec<usize>> = Vec::new();
            run_pipeline(&ds, &cfg, |b| {
                let mut v = b.indices.clone();
                v.sort_unstable();
                got.push(v);
            })
            .unwrap();
            got.sort();
            got
        };
        let evolving = run(BatchStrategy::Evolving {
            cfg: AbaConfig::default(),
            shuffle_seed: 9,
            churn: 0,
            refine_budget: 0,
        });
        let fixed = run(BatchStrategy::Aba { cfg: AbaConfig::default(), shuffle_seed: 9 });
        assert_eq!(evolving, fixed);
    }
}
