"""AOT path: the emitted HLO text and manifest are well-formed and the HLO
round-trips numerically through a fresh PJRT compile in python (the same
engine the Rust runtime embeds)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels.ref import cost_matrix_ref

ART = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..",
                                   "artifacts"))

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


def _manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_lists_every_bucket():
    man = _manifest()
    names = {e["name"] for e in man["entries"]}
    for m, k, d in aot.COST_BUCKETS:
        assert f"cost_m{m}_k{k}_d{d}" in names
    for n, d in aot.DIST_BUCKETS:
        assert f"dist_n{n}_d{d}" in names
        assert f"csum_n{n}_d{d}" in names


def test_manifest_entries_have_files_and_shapes():
    man = _manifest()
    assert man["format"] == 1
    for e in man["entries"]:
        path = os.path.join(ART, e["file"])
        assert os.path.exists(path), e["file"]
        assert os.path.getsize(path) > 100
        assert e["kind"] in ("cost", "dist", "csum")
        assert all(isinstance(s, list) for s in e["inputs"])


def test_hlo_text_is_parseable_header():
    man = _manifest()
    for e in man["entries"]:
        with open(os.path.join(ART, e["file"])) as f:
            head = f.read(200)
        assert head.startswith("HloModule"), e["file"]


def test_cost_hlo_contains_dot_op():
    """The Pallas cross term must lower to a dot (MXU-shaped), not an
    elementwise blowup."""
    man = _manifest()
    cost = [e for e in man["entries"] if e["kind"] == "cost"]
    assert cost
    for e in cost:
        with open(os.path.join(ART, e["file"])) as f:
            text = f.read()
        assert " dot(" in text or " dot." in text, e["file"]


def test_hlo_text_reparses_with_xla():
    """The emitted text must be reconstructible by XLA's HLO parser — the
    exact operation the Rust runtime performs via
    ``HloModuleProto::from_text_file``. (The full load-compile-execute
    numeric round-trip is covered by the Rust integration test
    ``rust/tests/runtime_roundtrip.rs``.)"""
    from jax._src.lib import xla_client as xc

    for e in _manifest()["entries"]:
        with open(os.path.join(ART, e["file"])) as f:
            text = f.read()
        mod = xc._xla.hlo_module_from_text(text)
        # Parsed module keeps the tuple root with the advertised shape.
        assert mod is not None, e["file"]


def test_regenerating_artifacts_is_deterministic(tmp_path):
    """aot.build_entries lowers deterministically: same text twice."""
    ent = aot.build_entries()
    name, lowered, meta = next(ent)
    t1 = aot.to_hlo_text(lowered)
    lowered2 = next(aot.build_entries())[1]
    t2 = aot.to_hlo_text(lowered2)
    assert t1 == t2
