"""L1 correctness: Pallas cost-matrix kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compile path: every artifact
the Rust runtime executes is a lowering of the functions tested here.
Hypothesis sweeps shapes, dtypes, scales and degenerate inputs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.cost_matrix import (
    cost_matrix,
    mxu_flops,
    vmem_bytes,
    _pick_block,
)
from compile.kernels.ref import (
    centroid_distances_ref,
    cost_matrix_ref,
    global_centroid_ref,
    within_group_ssd_ref,
)

RNG = np.random.default_rng(0)


def _rand(m, d, scale=1.0, dtype=np.float32):
    return (RNG.standard_normal((m, d)) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Deterministic fixed-shape checks (the shipped bucket shapes).
# ---------------------------------------------------------------------------

BUCKETS = [(64, 64, 16), (128, 128, 32), (128, 128, 64), (256, 256, 64),
           (256, 256, 128)]


@pytest.mark.parametrize("m,k,d", BUCKETS)
def test_kernel_matches_ref_on_shipped_buckets(m, k, d):
    x, c = _rand(m, d), _rand(k, d)
    got = np.asarray(cost_matrix(x, c))
    want = np.asarray(cost_matrix_ref(x, c))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_kernel_zero_distance_diagonal():
    # Distance from a point to itself must be exactly clamped >= 0 and ~0.
    x = _rand(32, 8)
    got = np.asarray(cost_matrix(x, x))
    assert np.all(got >= 0.0)
    np.testing.assert_allclose(np.diag(got), 0.0, atol=1e-3)


def test_kernel_single_centroid_column():
    x = _rand(64, 16)
    c = _rand(1, 16)
    got = np.asarray(cost_matrix(x, c, bk=1))
    want = np.asarray(centroid_distances_ref(x, c[0]))
    np.testing.assert_allclose(got[:, 0], want, rtol=1e-4, atol=1e-4)


def test_kernel_rejects_mismatched_feature_dims():
    with pytest.raises(ValueError, match="feature dims differ"):
        cost_matrix(_rand(4, 3), _rand(4, 5))


def test_kernel_rejects_rank1():
    with pytest.raises(ValueError, match="2-D"):
        cost_matrix(np.zeros(4, np.float32), _rand(4, 4))


def test_kernel_rejects_nondividing_tiles():
    with pytest.raises(ValueError, match="divide"):
        cost_matrix(_rand(10, 4), _rand(10, 4), bm=3)


def test_kernel_translation_invariance():
    # Squared distances are invariant to a common translation.
    x, c = _rand(32, 8), _rand(16, 8)
    t = _rand(1, 8, scale=10.0)
    a = np.asarray(cost_matrix(x, c))
    b = np.asarray(cost_matrix(x + t, c + t))
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-2)


def test_kernel_accepts_float64_input_casts_to_f32():
    x = _rand(16, 4, dtype=np.float64)
    c = _rand(8, 4, dtype=np.float64)
    got = np.asarray(cost_matrix(x, c))
    assert got.dtype == np.float32
    np.testing.assert_allclose(got, cost_matrix_ref(x, c), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# Hypothesis sweeps: random shapes, tile sizes, scales.
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    d=st.integers(1, 48),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_random_shapes(m, k, d, scale, seed):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((m, d)) * scale).astype(np.float32)
    c = (rng.standard_normal((k, d)) * scale).astype(np.float32)
    got = np.asarray(cost_matrix(x, c))
    want = np.asarray(cost_matrix_ref(x, c))
    tol = 1e-3 * max(scale * scale, 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=tol)
    assert np.all(got >= 0.0)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(2, 64),
    d=st.integers(1, 32),
    bm=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_tile_size_does_not_change_result(m, d, bm, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, d)).astype(np.float32)
    c = rng.standard_normal((m, d)).astype(np.float32)
    # Snap bm to a divisor of m so the request is valid.
    bm = _pick_block(m, bm)
    a = np.asarray(cost_matrix(x, c, bm=bm))
    b = np.asarray(cost_matrix(x, c))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 40),
    d=st.integers(1, 8),
    k=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_fact1_pairwise_equals_centroid_form(n, d, k, seed):
    """Fact 1: sum_{i<i'} ||xi - xi'||^2 == n_k * sum_i ||xi - mu_k||^2."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    labels = rng.integers(0, k, n)
    lhs = within_group_ssd_ref(x, labels, k)
    rhs = 0.0
    for g in range(k):
        pts = x[labels == g]
        if len(pts) == 0:
            continue
        mu = pts.mean(axis=0)
        rhs += len(pts) * float(((pts - mu) ** 2).sum())
    assert lhs == pytest.approx(rhs, rel=1e-3, abs=1e-3)


# ---------------------------------------------------------------------------
# Footprint estimators used in DESIGN.md reporting.
# ---------------------------------------------------------------------------

def test_vmem_estimate_of_largest_bucket_fits_tpu_vmem():
    # (256,256,128) runs as 128x128 tiles with full D resident.
    assert vmem_bytes(128, 128, 128) < 16 * 2**20 / 8  # << 16 MiB VMEM


def test_mxu_flops_counts_cross_term():
    assert mxu_flops(2, 3, 4) == 2 * 2 * 3 * 4


def test_pick_block_returns_divisor():
    for n in range(1, 200):
        b = _pick_block(n, 128)
        assert n % b == 0 and 1 <= b <= min(n, 128)
