"""L2 correctness: model graphs (shapes, semantics) vs oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import (
    centroid_distances_ref,
    cost_matrix_ref,
    global_centroid_ref,
)

RNG = np.random.default_rng(1)


def test_batch_costs_returns_1tuple_with_expected_shape():
    x = RNG.standard_normal((64, 16)).astype(np.float32)
    c = RNG.standard_normal((64, 16)).astype(np.float32)
    out = model.batch_costs(x, c)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (64, 64)
    np.testing.assert_allclose(np.asarray(out[0]), cost_matrix_ref(x, c),
                               rtol=1e-4, atol=1e-4)


def test_centroid_distances_matches_ref():
    x = RNG.standard_normal((128, 32)).astype(np.float32)
    mu = RNG.standard_normal((1, 32)).astype(np.float32)
    (got,) = model.centroid_distances(x, mu)
    want = centroid_distances_ref(x, mu[0])
    assert got.shape == (128,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-4)


def test_chunk_centroid_sums_columns():
    x = RNG.standard_normal((64, 8)).astype(np.float32)
    (got,) = model.chunk_centroid(x)
    assert got.shape == (1, 8)
    np.testing.assert_allclose(np.asarray(got)[0], x.sum(axis=0), rtol=1e-4,
                               atol=1e-4)


def test_chunked_centroid_accumulation_equals_global_mean():
    """The Rust runtime sums csum chunks and divides by N — verify the
    contract end to end in python."""
    x = RNG.standard_normal((4 * 32, 8)).astype(np.float32)
    acc = np.zeros((1, 8), np.float32)
    for i in range(4):
        (s,) = model.chunk_centroid(x[i * 32:(i + 1) * 32])
        acc += np.asarray(s)
    mu = acc[0] / x.shape[0]
    np.testing.assert_allclose(mu, np.asarray(global_centroid_ref(x)),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 64), d=st.integers(1, 16),
       seed=st.integers(0, 2**31 - 1))
def test_centroid_distances_random(n, d, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    mu = rng.standard_normal((1, d)).astype(np.float32)
    (got,) = model.centroid_distances(x, mu)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(centroid_distances_ref(x, mu[0])),
                               rtol=1e-3, atol=1e-3)


def test_zero_padding_d_preserves_distances():
    """The Rust runtime zero-pads the feature dim up to a bucket's D; padding
    both operands with zero columns must not change squared distances."""
    x = RNG.standard_normal((32, 10)).astype(np.float32)
    c = RNG.standard_normal((16, 10)).astype(np.float32)
    xp = np.pad(x, ((0, 0), (0, 6)))
    cp = np.pad(c, ((0, 0), (0, 6)))
    a = np.asarray(model.batch_costs(x, c)[0])
    b = np.asarray(model.batch_costs(xp, cp)[0])
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_row_padding_is_croppable():
    """Padding extra object rows only appends rows; the top-left block is
    unchanged, so the runtime can crop."""
    x = RNG.standard_normal((24, 8)).astype(np.float32)
    c = RNG.standard_normal((16, 8)).astype(np.float32)
    xp = np.pad(x, ((0, 8), (0, 0)))
    a = np.asarray(model.batch_costs(x, c)[0])
    b = np.asarray(model.batch_costs(xp, c)[0])
    np.testing.assert_allclose(a, b[:24], rtol=1e-5, atol=1e-5)
