"""L2 — JAX compute graphs for the ABA algorithm.

These are the functions that get AOT-lowered (by ``aot.py``) to HLO text
and executed from the Rust coordinator via PJRT. Each calls into the L1
Pallas kernel where the hot compute lives:

* ``batch_costs``        — the per-batch (M, K) object↔centroid squared
                           distance matrix fed to LAPJV (Algorithm 1 inner
                           loop). Cross term via the Pallas kernel.
* ``centroid_distances`` — distances of a chunk of objects to the global
                           centroid (Algorithm 1 preamble, used to build
                           the sorted list N↓).
* ``chunk_centroid``     — sum + count of a chunk of rows, for the
                           streaming global-centroid computation.

All functions return tuples so that the lowered HLO has a tuple root
(``return_tuple=True``), which the Rust side unwraps with ``to_tuple1``.
Shapes are fixed at lowering time; the Rust runtime pads/crops to the
nearest shipped bucket (see DESIGN.md §Shape buckets).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.cost_matrix import cost_matrix


def batch_costs(x, c):
    """(M, K) squared-distance cost matrix for one assignment batch.

    This is the request-path hot spot: Algorithm 1 calls it once per batch
    (ceil(N/K) - 1 times per run).
    """
    return (cost_matrix(x, c),)


def centroid_distances(x, mu):
    """(N,) squared distances of each row of ``x`` to the centroid ``mu``.

    ``mu`` arrives as shape (1, D) so the artifact I/O stays rank-2.
    Implemented via the same Pallas kernel with K = 1: the cross term is a
    (N, D) x (D, 1) matvec on the MXU.
    """
    d = cost_matrix(x, mu, bk=1)  # (N, 1)
    return (d[:, 0],)


def chunk_centroid(x):
    """Column sums of a chunk of rows; Rust accumulates across chunks and
    divides by N to obtain the global centroid without a second pass."""
    return (jnp.sum(x, axis=0, keepdims=True),)
