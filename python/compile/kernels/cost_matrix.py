"""L1 — Pallas kernel for the ABA cost matrix.

The hot numeric kernel of the Assignment-Based Anticlustering algorithm:
given a batch of objects ``X`` of shape ``(M, D)`` and the current
anticluster centroids ``C`` of shape ``(K, D)``, compute the ``(M, K)``
matrix of *squared Euclidean distances*

    cost[i, k] = ||x_i - c_k||^2 = ||x_i||^2 + ||c_k||^2 - 2 <x_i, c_k>

which Algorithm 1 of the paper hands to the LAPJV max-cost assignment
solver once per batch.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the dominant term is the
cross product ``X @ C.T`` — an MXU-shaped matmul — while the row/column
norms are cheap VPU reductions broadcast over the tile. We tile ``M`` and
``K`` with BlockSpec and keep the full feature dimension ``D`` resident in
VMEM per tile; for the shipped buckets (D <= 128) a (128, D) x (D, 128)
tile plus the (128, 128) output is well under 1 MB of VMEM, leaving room
for double buffering.

The kernel MUST be run with ``interpret=True`` on this CPU image: real TPU
lowering emits a Mosaic custom-call that the CPU PJRT plugin cannot
execute. ``interpret=True`` lowers to plain HLO, which is exactly what the
Rust runtime loads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cost_matrix_kernel(x_ref, c_ref, o_ref):
    """One (bm, bk) output tile of the squared-distance matrix."""
    x = x_ref[...]  # (bm, D) block of objects
    c = c_ref[...]  # (bk, D) block of centroids
    # Row norms ||x_i||^2 -> (bm, 1); column norms ||c_k||^2 -> (1, bk).
    xn = jnp.sum(x * x, axis=1, keepdims=True)
    cn = jnp.sum(c * c, axis=1, keepdims=True).T
    # Cross term on the MXU: contract the feature dimension of both
    # operands without materializing a transpose of C.
    cross = jax.lax.dot_general(
        x,
        c,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # Clamp tiny negative values produced by cancellation so downstream
    # consumers can rely on costs >= 0.
    o_ref[...] = jnp.maximum(xn + cn - 2.0 * cross, 0.0)


def _pick_block(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= target (grid must tile evenly)."""
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bk"))
def _cost_matrix_jit(x, c, bm: int, bk: int):
    m, d = x.shape
    k, _ = c.shape
    grid = (m // bm, k // bk)
    return pl.pallas_call(
        _cost_matrix_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=True,
    )(x, c)


def cost_matrix(x: jax.Array, c: jax.Array, *, bm: int | None = None,
                bk: int | None = None) -> jax.Array:
    """Squared Euclidean distance matrix between rows of ``x`` and ``c``.

    Args:
      x: ``(M, D)`` float32 batch of objects.
      c: ``(K, D)`` float32 anticluster centroids.
      bm, bk: optional tile sizes; default picks the largest divisor of
        M (resp. K) that is <= 128, matching the MXU-friendly schedule.

    Returns:
      ``(M, K)`` float32 matrix of non-negative squared distances.
    """
    if x.ndim != 2 or c.ndim != 2:
        raise ValueError(f"expected 2-D operands, got {x.shape} and {c.shape}")
    if x.shape[1] != c.shape[1]:
        raise ValueError(
            f"feature dims differ: x has D={x.shape[1]}, c has D={c.shape[1]}")
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    m, _ = x.shape
    k, _ = c.shape
    bm = bm if bm is not None else _pick_block(m, 128)
    bk = bk if bk is not None else _pick_block(k, 128)
    if m % bm != 0 or k % bk != 0:
        raise ValueError(f"tile sizes ({bm},{bk}) must divide ({m},{k})")
    return _cost_matrix_jit(x, c, bm, bk)


def vmem_bytes(bm: int, bk: int, d: int) -> int:
    """Estimated VMEM residency of one tile invocation (f32, single-buffered).

    Used by DESIGN.md / EXPERIMENTS.md to report the TPU footprint of the
    shipped shape buckets.
    """
    return 4 * (bm * d + bk * d + bm * bk)


def mxu_flops(m: int, k: int, d: int) -> int:
    """MXU FLOP count of the cross-term matmul for a full (m, k, d) call."""
    return 2 * m * k * d
