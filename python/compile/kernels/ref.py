"""Pure-jnp oracles for the Pallas kernels.

These are the *correctness references*: straightforward, obviously-right
jax.numpy implementations against which the Pallas kernels are checked in
``python/tests``. They are never exported to artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp


def cost_matrix_ref(x, c):
    """(M, K) squared Euclidean distances, the obvious way.

    cost[i, k] = sum_d (x[i, d] - c[k, d])^2
    """
    x = jnp.asarray(x, jnp.float32)
    c = jnp.asarray(c, jnp.float32)
    diff = x[:, None, :] - c[None, :, :]  # (M, K, D)
    return jnp.sum(diff * diff, axis=-1)


def centroid_distances_ref(x, mu):
    """(N,) squared Euclidean distances from each row of ``x`` to ``mu``."""
    x = jnp.asarray(x, jnp.float32)
    mu = jnp.asarray(mu, jnp.float32)
    diff = x - mu[None, :]
    return jnp.sum(diff * diff, axis=-1)


def global_centroid_ref(x):
    """(D,) mean of the rows of ``x``."""
    return jnp.mean(jnp.asarray(x, jnp.float32), axis=0)


def within_group_ssd_ref(x, labels, k):
    """Fact 1 left-hand side: sum over groups of pairwise squared distances.

    Quadratic in group size — only usable for small test instances, which
    is exactly the point: it is the independent ground truth for the
    centroid-based objective used everywhere else.
    """
    x = jnp.asarray(x, jnp.float32)
    total = 0.0
    for g in range(k):
        pts = x[jnp.asarray(labels) == g]
        n = pts.shape[0]
        for i in range(n):
            for j in range(i + 1, n):
                d = pts[i] - pts[j]
                total += float(jnp.dot(d, d))
    return total
