"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

This script is the only place Python runs in the whole system, and it runs
once, at build time (``make artifacts``). It lowers each L2 function at
every shipped shape bucket and writes:

    artifacts/<name>.hlo.txt   — HLO text, one per (function, bucket)
    artifacts/manifest.json    — bucket registry the Rust runtime reads

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
bundled XLA (xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py, whose recipe this follows.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.cost_matrix import vmem_bytes, mxu_flops

# ---------------------------------------------------------------------------
# Shape-bucket registry.
#
# The Rust runtime pads an (m, k, d) request up to the smallest bucket that
# fits and crops the result; requests larger than every bucket fall back to
# the native Rust backend. Buckets are chosen so the Pallas tile schedule
# (<=128x128 tiles, full D resident) stays far below TPU VMEM (~16 MiB).
# ---------------------------------------------------------------------------

COST_BUCKETS = [
    # (M, K, D)
    (64, 64, 16),
    (128, 128, 32),
    (128, 128, 64),
    (256, 256, 64),
    (256, 256, 128),
]

DIST_BUCKETS = [
    # (N, D) — centroid_distances chunks
    (1024, 16),
    (1024, 32),
    (1024, 64),
    (1024, 128),
]

CSUM_BUCKETS = DIST_BUCKETS  # chunk_centroid uses the same chunking


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def build_entries():
    """Yield (name, lowered, meta) for every artifact to emit."""
    for m, k, d in COST_BUCKETS:
        name = f"cost_m{m}_k{k}_d{d}"
        lowered = jax.jit(model.batch_costs).lower(_spec(m, d), _spec(k, d))
        meta = {
            "kind": "cost",
            "m": m,
            "k": k,
            "d": d,
            "inputs": [[m, d], [k, d]],
            "output": [m, k],
            "vmem_bytes_tile": vmem_bytes(min(m, 128), min(k, 128), d),
            "mxu_flops": mxu_flops(m, k, d),
        }
        yield name, lowered, meta
    for n, d in DIST_BUCKETS:
        name = f"dist_n{n}_d{d}"
        lowered = jax.jit(model.centroid_distances).lower(
            _spec(n, d), _spec(1, d))
        meta = {
            "kind": "dist",
            "n": n,
            "d": d,
            "inputs": [[n, d], [1, d]],
            "output": [n],
        }
        yield name, lowered, meta
    for n, d in CSUM_BUCKETS:
        name = f"csum_n{n}_d{d}"
        lowered = jax.jit(model.chunk_centroid).lower(_spec(n, d))
        meta = {
            "kind": "csum",
            "n": n,
            "d": d,
            "inputs": [[n, d]],
            "output": [1, d],
        }
        yield name, lowered, meta


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=None,
                    help="artifact directory (default: ../artifacts)")
    # Back-compat with the scaffold Makefile's `--out path/model.hlo.txt`.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    out_dir = args.out_dir
    if out_dir is None and args.out is not None:
        out_dir = os.path.dirname(args.out) or "."
    if out_dir is None:
        out_dir = os.path.join(os.path.dirname(__file__), "..", "..",
                               "artifacts")
    out_dir = os.path.abspath(out_dir)
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"format": 1, "entries": []}
    for name, lowered, meta in build_entries():
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        meta = dict(meta, name=name, file=fname)
        manifest["entries"].append(meta)
        print(f"  wrote {fname}  ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json with {len(manifest['entries'])} entries "
          f"to {out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
