//! Build probe for the AVX-512 kernel arm.
//!
//! The crate's MSRV (1.75) predates stable AVX-512 intrinsics and
//! `#[target_feature(enable = "avx512f")]` (both stabilized in 1.89), so
//! the AVX-512 fast-math kernels in `runtime/simd.rs` are gated behind a
//! `aba_avx512` cfg that this script emits only when the compiling
//! `rustc` is new enough. Older toolchains simply compile without the
//! arm and `KernelMode::FastMath` degrades to the AVX2+FMA table — the
//! same graceful fallback a host without the ISA gets at runtime.

use std::process::Command;

/// `(major, minor)` of the compiling rustc, or `None` when the version
/// string cannot be parsed (pessimistic: no cfg gets emitted).
fn rustc_version() -> Option<(u32, u32)> {
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let out = Command::new(rustc).arg("--version").output().ok()?;
    let text = String::from_utf8(out.stdout).ok()?;
    // "rustc 1.89.0 (abc123 2025-07-01)" — second whitespace field.
    let version = text.split_whitespace().nth(1)?;
    let mut parts = version.split(['.', '-', '+']);
    let major = parts.next()?.parse().ok()?;
    let minor = parts.next()?.parse().ok()?;
    Some((major, minor))
}

fn main() {
    println!("cargo:rerun-if-changed=build.rs");
    let version = rustc_version();
    // `rustc-check-cfg` itself needs cargo >= 1.80; on older toolchains
    // the custom cfg is also absent, so nothing trips `unexpected_cfgs`.
    if matches!(version, Some((major, minor)) if major > 1 || (major == 1 && minor >= 80)) {
        println!("cargo:rustc-check-cfg=cfg(aba_avx512)");
    }
    if matches!(version, Some((major, minor)) if major > 1 || (major == 1 && minor >= 89)) {
        println!("cargo:rustc-cfg=aba_avx512");
    }
}
